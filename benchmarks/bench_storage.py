"""Tables 3/4/5 (dataset sizes + selective reading) and Table 6 (I/O sizes)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us
from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.reader import TableReader, plan_reads
from repro.core.schema import make_schema
from repro.core.warehouse import Warehouse


def run() -> None:
    # RM1-shaped table at reduced scale: 12k+1.8k features -> 1:10 scale
    schema = make_schema("rm1", n_dense=1200, n_sparse=180, seed=0)
    wh = Warehouse()
    t = wh.create_table(schema)
    us = time_us(
        lambda: t.generate(
            1, DataGenConfig(rows_per_partition=1024, seed=1),
            dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
        ),
        repeat=1,
    )
    emit("table3.write_partition", us, f"partition_bytes={t.partitions[0].nbytes}")

    # a representative job projection: ~11% of features, popularity-weighted
    rng = np.random.default_rng(0)
    fids = np.array(schema.logged_ids)
    pops = np.array([schema.feature(f).popularity for f in fids]); pops /= pops.sum()
    proj = sorted(rng.choice(fids, size=len(fids) // 9, replace=False, p=pops).tolist())
    reader = TableReader(t, proj)
    stats = reader.projection_stats()
    emit(
        "table5.selective_reading", 0.0,
        f"pct_features={stats['pct_features_used']:.1f} "
        f"pct_bytes={stats['pct_bytes_used']:.1f} (paper: 9-11% / 21-37%)",
    )

    # Table 6: I/O sizes WITHOUT coalescing (raw per-stream reads)
    plan = plan_reads(t.partitions[0].footer, proj, coalesce_window=0)
    sizes = np.array([l for _, l in plan.extents])
    emit(
        "table6.io_sizes_uncoalesced", 0.0,
        f"mean={sizes.mean():.0f}B p5={np.percentile(sizes,5):.0f} "
        f"p50={np.percentile(sizes,50):.0f} p95={np.percentile(sizes,95):.0f} "
        f"n_ios={len(sizes)} (paper: mean 23.2KB p50 1.24KB)",
    )

    us = time_us(lambda: reader.read_partition(t.partitions[0]), repeat=2)
    emit("table5.read_projection", us, f"rows=1024")
