import time

import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPMaster, DPPSession, SessionSpec
from repro.core.schema import make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse

# whole-module lock-order sanitizer coverage (ISSUE 8): every DPP test
# runs under lockdep via the marker-driven autouse fixture in conftest
pytestmark = pytest.mark.lockdep


def _table(n_partitions=2, rows=1024):
    s = make_schema("dpt", 20, 6, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(n_partitions, DataGenConfig(rows_per_partition=rows, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    return t


def _spec(t, **kw):
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    d = dict(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=256, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )
    d.update(kw)
    return SessionSpec(**d)


def test_session_one_epoch_exact_batches():
    t = _table()
    sess = DPPSession(_spec(t), t, n_workers=2)
    batches = sess.run_to_completion(timeout_s=60)
    assert len(batches) == 2 * 1024 // 256
    assert batches[0]["dense"].shape == (256, 6)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 1024


def test_worker_failure_restart_completes_epoch():
    t = _table()
    # the ONLY worker dies after 2 splits; the monitor must restart it or the
    # epoch cannot complete
    sess = DPPSession(_spec(t), t, n_workers=1, lease_s=1.0, monitor_interval_s=0.1)
    sess.workers[0].fail_after_splits = 2
    batches = sess.run_to_completion(timeout_s=60)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 1024
    assert len(sess.restart_events) >= 1


def test_master_checkpoint_restore_resumes():
    t = _table()
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows)
    s1 = m.get_split("w0"); m.complete_split("w0", s1.split_id)
    s2 = m.get_split("w0"); m.complete_split("w0", s2.split_id)
    ckpt = m.checkpoint()
    m2 = DPPMaster.restore(ckpt, rows)
    done, total = m2.progress
    assert done == 2
    seen = set()
    while True:
        s = m2.get_split("w1")
        if s is None:
            break
        seen.add(s.split_id)
        m2.complete_split("w1", s.split_id)
    assert s1.split_id not in seen and s2.split_id not in seen
    assert m2.finished


def test_straggler_lease_redispatch():
    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.05)
    s = m.get_split("slow")
    time.sleep(0.1)   # lease expires; straggler mitigation re-dispatches
    s2 = m.get_split("fast")
    assert s2.split_id == s.split_id


def test_lease_expiry_deterministic_with_injected_clock():
    """The REPRO-C001 payoff: lease/heartbeat logic is driven by a fake
    clock — no sleeps, no wall-clock flakiness."""
    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    now = [1000.0]
    m = DPPMaster(spec, rows, lease_s=30.0, clock=lambda: now[0])
    s = m.get_split("slow")
    now[0] += 29.0                      # inside the lease: still held
    s_f = m.get_split("fast")
    assert s_f.split_id != s.split_id
    m.heartbeat("slow")                 # extends the deadline to now+30
    now[0] += 5.0
    assert m.dead_workers(timeout_s=10.0) == []
    now[0] += 27.0                      # both leases now expired
    assert set(m.dead_workers(timeout_s=10.0)) == {"slow", "fast"}
    # straggler mitigation reclaims and re-dispatches both expired splits
    redispatched = {m.get_split("fresh").split_id,
                    m.get_split("fresh").split_id}
    assert redispatched == {s.split_id, s_f.split_id}


def test_forget_worker_releases_leases():
    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=100.0)
    s = m.get_split("dead")
    m.forget_worker("dead")
    s2 = m.get_split("alive")
    assert s2.split_id == s.split_id


def test_autoscaling_session_scales_out():
    t = _table(n_partitions=2, rows=2048)
    sess = DPPSession(_spec(t), t, n_workers=1, auto_scale=True,
                      monitor_interval_s=0.05, max_workers=4)
    batches = sess.run_to_completion(timeout_s=90)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 2048


# -- client satellites (ISSUE 3) ---------------------------------------------


class _StubWorker:
    """Just enough of DPPWorker's serving surface for client unit tests."""

    def __init__(self, batches=()):
        self.alive = True
        self._q = list(batches)

    @property
    def buffered(self):
        return len(self._q)

    def get_batch(self, timeout=0.0):
        return self._q.pop(0) if self._q else None


def test_client_partition_offset_is_stable_digest():
    import zlib

    from repro.core.dpp import DPPClient

    workers = [_StubWorker() for _ in range(8)]
    c = DPPClient("trainer-3", workers)
    # crc32, not hash(): identical across processes whatever PYTHONHASHSEED
    assert c._partition_offset == zlib.crc32(b"trainer-3") % 8


def test_client_stall_accounting_only_on_actual_stall():
    from repro.core.dpp import DPPClient

    w = _StubWorker([{"x": np.zeros(4, np.float32)}])
    c = DPPClient("c0", [w])
    assert c.get_batch(timeout=1.0) is not None
    # batch was available immediately: NO stall time may accrue
    assert c.metrics.stalls == 0
    assert c.metrics.stall_s == 0.0
    # now the buffer is empty and the worker produces nothing
    t0 = time.perf_counter()
    assert c.get_batch(timeout=0.05) is None
    waited = time.perf_counter() - t0
    assert c.metrics.stalls == 1
    assert 0.0 < c.metrics.stall_s <= waited + 0.01


def test_concat_labels_raises_on_mixed_labeling():
    from repro.core.dpp.worker import _concat_labels

    labeled = ({}, np.ones(4, np.float32), 4)
    unlabeled = ({}, None, 4)
    assert _concat_labels([unlabeled, unlabeled]) is None
    np.testing.assert_array_equal(
        _concat_labels([labeled, labeled]), np.ones(8, np.float32)
    )
    with pytest.raises(ValueError, match="mixed labeled/unlabeled"):
        _concat_labels([labeled, unlabeled])


# -- prefetch planner (ISSUE 3) ----------------------------------------------


def test_prefetch_planner_warms_only_uncached_segments():
    from repro.core.cache import StripeCache
    from repro.core.dpp import DPPMaster, PrefetchPlanner

    s = make_schema("pf", 20, 6, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(2, DataGenConfig(rows_per_partition=1024, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    cache = StripeCache()
    wh.attach_cache(cache)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, partition_stripe_rows={p: 256 for p in spec.partitions})

    planner = PrefetchPlanner(t, m, spec.feature_ids, tenant="job", depth=32)
    fetched = planner.prefetch_once()
    assert fetched > 0
    assert planner.metrics.splits_warmed > 0
    # everything upcoming is now cached: a second pass fetches nothing
    planner2 = PrefetchPlanner(t, m, spec.feature_ids, tenant="job", depth=32)
    assert planner2.prefetch_once() == 0
    assert planner2.metrics.bytes_already_cached > 0
    # and the worker read path is served from the cache, byte-identical
    from repro.core.reader import TableReader

    r = TableReader(t, spec.feature_ids, record_popularity=False, tenant="job")
    res = r.read_rows(t.partitions[0], 0, 256)
    assert res.bytes_from_storage == 0
    assert res.bytes_from_cache == res.bytes_read
    # prefetched bytes are charged to the prefetching tenant
    assert cache.tenants["job"].bytes_stored > 0
    # a partition rewrite bumps the generation: its splits become warmable
    # again instead of being skipped forever on stale cached bytes
    from repro.core.datagen import generate_partition

    t.rewrite_partition(
        0, generate_partition(s, 0, DataGenConfig(rows_per_partition=1024, seed=9)),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
    )
    assert planner2.prefetch_once() > 0


# -- fault-tolerant control plane (ISSUE 4) ----------------------------------


def _poisoned_table(n_healthy=2, rows=1024, name="poison", head_rows=256):
    """``n_healthy`` good partitions plus one whose stripes are mixed
    labeled/unlabeled — poisoned: extract/transform deterministically
    raises on it, whichever worker draws it."""
    from repro.core.datagen import generate_partition

    s = make_schema(name, 20, 6, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    opts = dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256)
    t.generate(n_healthy, DataGenConfig(rows_per_partition=rows, seed=1), opts)
    head = dwrf.write_dwrf(
        generate_partition(s, n_healthy,
                           DataGenConfig(rows_per_partition=head_rows, seed=2)),
        opts,
    )
    tail = dwrf.write_dwrf(
        generate_partition(
            s, n_healthy,
            DataGenConfig(rows_per_partition=rows - head_rows, seed=3,
                          labeled=False),
        ),
        opts,
    )
    t.write_partition_encoded(n_healthy, dwrf.concat_dwrf([head, tail]))
    return t


def test_poisoned_split_degrades_within_budget_and_drains_healthy():
    from repro.core.dpp import SessionState

    budget, lease_s = 2, 2.0
    t = _poisoned_table()
    sess = DPPSession(
        _spec(t, batch_size=512, rows_per_split=1024), t,
        n_workers=2, lease_s=lease_s, dispatch_budget=budget,
    )
    t0 = time.time()
    batches = sess.run_to_completion(timeout_s=60)
    elapsed = time.time() - t0
    # terminates within budget x lease — no livelock on worker restarts
    assert elapsed <= budget * lease_s, elapsed
    assert sess.state == SessionState.DEGRADED
    # healthy splits' batches are still delivered, exactly
    assert sum(b["label"].shape[0] for b in batches) == 2 * 1024
    # the offending split + _concat_labels exception chain is surfaced
    [f] = sess.failure_report()
    assert f.partition == 2 and f.dispatches == budget
    assert all(s == "data_error" for s in f.statuses)
    assert "mixed labeled/unlabeled" in f.last_error
    # full traceback is surfaced (raising frame + exception), not a repr
    assert "Traceback" in f.last_error and "process_split" in f.last_error
    # data errors did NOT kill workers: no restart churn
    assert sess.restart_events == []


def test_poisoned_split_detected_on_batch_aligned_boundary():
    """The label transition lands exactly on a batch-aligned drain
    boundary (head rows % batch_size == 0, zero carry): the per-window
    ``_concat_labels`` guard alone would miss it, so the worker's
    per-split uniformity check must still raise the data_error."""
    from repro.core.dpp import SessionState

    t = _poisoned_table(n_healthy=1, name="poisonb", head_rows=512)
    sess = DPPSession(
        _spec(t, batch_size=256, rows_per_split=1024), t,
        n_workers=1, lease_s=2.0, dispatch_budget=2,
    )
    batches = sess.run_to_completion(timeout_s=60)
    assert sess.state == SessionState.DEGRADED
    # every delivered batch is labeled; none of the poisoned split's
    # unlabeled rows slipped through silently
    assert all("label" in b for b in batches)
    assert sum(b["label"].shape[0] for b in batches) == 1024
    [f] = sess.failure_report()
    assert "mixed labeled/unlabeled" in f.last_error


def test_all_splits_poisoned_raises_session_failed():
    from repro.core.dpp import SessionFailed, SessionState

    t = _poisoned_table(n_healthy=0, name="poisonf")
    sess = DPPSession(
        _spec(t, batch_size=512, rows_per_split=1024), t,
        n_workers=1, lease_s=2.0, dispatch_budget=2,
    )
    with pytest.raises(SessionFailed) as ei:
        sess.run_to_completion(timeout_s=60)
    assert sess.state == SessionState.FAILED
    assert ei.value.state == SessionState.FAILED
    assert len(ei.value.failures) == 1
    assert "mixed labeled/unlabeled" in ei.value.failures[0].last_error


def test_master_budget_quarantines_on_worker_lost():
    from repro.core.dpp import SessionState

    t = _table(n_partitions=1, rows=256)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.02, dispatch_budget=2)
    assert m.state == SessionState.RUNNING
    s = m.get_split("flaky")              # dispatch 1
    time.sleep(0.05)                      # lease expires: worker_lost
    s2 = m.get_split("flaky")             # reclaim + re-dispatch (2 = budget)
    assert s2 is not None and s2.split_id == s.split_id
    time.sleep(0.05)                      # second expiry exhausts the budget
    assert m.get_split("flaky") is None   # quarantined, never re-dispatched
    assert m.finished
    assert m.state == SessionState.FAILED
    [f] = m.failure_report()
    assert f.dispatches == 2
    assert all(s == "worker_lost" for s in f.statuses)
    assert "lease expired" in f.last_error


def test_master_data_error_requeues_then_quarantines():
    from repro.core.dpp import REPORT_DATA_ERROR

    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=100.0, dispatch_budget=2)
    s = m.get_split("w0")
    m.complete_split("w0", s.split_id, status=REPORT_DATA_ERROR, error="boom0")
    # under budget: re-queued at the front for a retry
    s2 = m.get_split("w1")
    assert s2.split_id == s.split_id
    m.complete_split("w1", s2.split_id, status=REPORT_DATA_ERROR, error="boom1")
    # budget exhausted: quarantined with the full per-dispatch chain
    assert s.split_id in m.quarantined
    f = m.quarantined[s.split_id]
    assert [r.error for r in f.reports] == ["boom0", "boom1"]
    assert [r.worker_id for r in f.reports] == ["w0", "w1"]


def test_master_checkpoint_preserves_quarantine():
    from repro.core.dpp import REPORT_DATA_ERROR, SessionState

    t = _table()
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, dispatch_budget=1)
    s = m.get_split("w0")
    m.complete_split("w0", s.split_id, status=REPORT_DATA_ERROR, error="bad")
    ckpt = m.checkpoint()
    m2 = DPPMaster.restore(ckpt, rows)
    # the quarantined split stays quarantined across Master failover
    assert s.split_id in m2.quarantined
    assert m2.quarantined[s.split_id].last_error == "bad"
    while True:
        nxt = m2.get_split("w1")
        if nxt is None:
            break
        assert nxt.split_id != s.split_id
        m2.complete_split("w1", nxt.split_id)
    assert m2.state == SessionState.DEGRADED


def test_heartbeat_extends_lease():
    t = _table(n_partitions=1, rows=256)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.05, dispatch_budget=1)
    s = m.get_split("slowpoke")
    # a slow-but-alive worker heartbeats through a long split: the lease
    # keeps extending and is never charged worker_lost
    for _ in range(4):
        time.sleep(0.03)
        m.heartbeat("slowpoke")
    assert m.get_split("thief") is None        # still exclusively leased
    assert m.failure_report() == []
    m.complete_split("slowpoke", s.split_id)
    assert m.finished and m.state == "COMPLETED"


def test_stale_report_from_superseded_dispatch_is_ignored():
    from repro.core.dpp import REPORT_DATA_ERROR, SessionState

    t = _table(n_partitions=1, rows=256)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.03, dispatch_budget=2)
    s = m.get_split("w0")
    time.sleep(0.05)                        # w0's lease expires (charge 1)
    s2 = m.get_split("w1")                  # re-dispatched to w1 (dispatch 2)
    assert s2.split_id == s.split_id
    # w0 wakes up and reports late: must not double-charge the budget nor
    # cancel w1's active lease
    m.complete_split("w0", s.split_id, status=REPORT_DATA_ERROR, error="late")
    assert s.split_id not in m.quarantined
    assert m.get_split("w2") is None        # w1 still holds the lease
    m.complete_split("w1", s2.split_id)     # current holder succeeds
    assert m.state == SessionState.COMPLETED


def test_late_ok_from_expired_lease_is_accepted():
    t = _table(n_partitions=1, rows=256)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.03, dispatch_budget=3)
    s = m.get_split("w0")
    time.sleep(0.05)
    s2 = m.get_split("w1")                  # straggler mitigation re-dispatch
    assert s2.split_id == s.split_id
    m.complete_split("w0", s.split_id)      # the straggler finishes first
    assert m.finished                       # done — whoever completed it
    m.complete_split("w1", s2.split_id)     # duplicate ok: no-op
    done, total = m.progress
    assert (done, total) == (1, 1)


def test_late_ok_un_quarantines_delivered_split():
    from repro.core.dpp import SessionState

    t = _table(n_partitions=1, rows=256)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.02, dispatch_budget=1)
    s = m.get_split("slow")
    time.sleep(0.05)
    m.get_split("other")                    # reclaim: budget 1 -> quarantine
    assert s.split_id in m.quarantined
    # the slow worker finished anyway and its batches were delivered: the
    # ok must un-quarantine, not mislabel delivered data as failed
    m.complete_split("slow", s.split_id)
    assert m.quarantined == {}
    assert m.state == SessionState.COMPLETED


def test_checkpoint_preserves_under_budget_failure_history():
    from repro.core.dpp import REPORT_DATA_ERROR

    t = _table(n_partitions=1, rows=256)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=100.0, dispatch_budget=2)
    s = m.get_split("w0")
    m.complete_split("w0", s.split_id, status=REPORT_DATA_ERROR, error="boom0")
    m2 = DPPMaster.restore(m.checkpoint(), rows, dispatch_budget=2)
    s2 = m2.get_split("w1")
    assert s2.split_id == s.split_id
    m2.complete_split("w1", s2.split_id, status=REPORT_DATA_ERROR, error="boom1")
    # the pre-failover report survived: the full chain is surfaced
    [f] = m2.failure_report()
    assert [r.error for r in f.reports] == ["boom0", "boom1"]


def test_drained_worker_retires_without_restart():
    t = _table()
    sess = DPPSession(_spec(t), t, n_workers=2, monitor_interval_s=0.05)
    victim = sess.workers[1]
    victim.retired = True
    victim.drain()
    batches = sess.run_to_completion(timeout_s=60)
    # the epoch is exact: draining never drops delivered rows
    assert sum(b["label"].shape[0] for b in batches) == 2 * 1024
    # the drained worker was removed, not "restarted" by the health check
    assert sess.restart_events == []
    assert victim not in sess.workers


def test_elastic_controller_hysteresis_and_cooldown():
    from repro.core.dpp import ElasticController, ElasticPolicy, Observation

    pol = ElasticPolicy(hysteresis_ticks=2, cooldown_ticks=2, max_workers=8)
    c = ElasticController(pol, prefetch_depth=4)
    stall = Observation(n_workers=2, buffered_batches=0, stall_rate=0.5,
                        cpu_util=1.0)
    calm = Observation(n_workers=2, buffered_batches=8, stall_rate=0.0,
                       cpu_util=0.6)
    # one transient stall tick does NOT scale (hysteresis)
    assert c.observe(stall).worker_delta == 0
    assert c.observe(calm).worker_delta == 0
    # sustained pressure for hysteresis_ticks does — and deepens prefetch
    assert c.observe(stall).worker_delta == 0
    d = c.observe(stall)
    assert d.worker_delta > 0
    assert d.prefetch_depth == 8
    # cooldown: even sustained pressure is a no-op while settling
    assert c.observe(stall).worker_delta == 0
    assert c.observe(stall).worker_delta == 0
    # cooldown expired + pressure persisted: acts again
    assert c.observe(stall).worker_delta > 0


def test_elastic_controller_scales_down_when_idle():
    from repro.core.dpp import ElasticController, ElasticPolicy, Observation

    pol = ElasticPolicy(hysteresis_ticks=2, cooldown_ticks=0, max_workers=8)
    c = ElasticController(pol, prefetch_depth=8)
    idle = Observation(n_workers=4, buffered_batches=100, stall_rate=0.0,
                       cpu_util=0.1)
    assert c.observe(idle).worker_delta == 0
    d = c.observe(idle)
    assert d.worker_delta < 0
    assert d.prefetch_depth == 4
    # never below min_workers
    floor = Observation(n_workers=1, buffered_batches=100, stall_rate=0.0,
                        cpu_util=0.0)
    assert c.observe(floor).worker_delta == 0
    assert c.observe(floor).worker_delta == 0


def test_tensor_cache_generation_aware_keys_after_rewrite():
    """ROADMAP staleness gap: a rewritten partition must never be served
    the pre-rewrite preprocessed tensors from the TensorCache."""
    from repro.core.datagen import generate_partition
    from repro.core.dpp.tensor_cache import TensorCache

    t = _table(n_partitions=1, rows=512)
    spec = _spec(t, partitions=(0,))
    cache = TensorCache()

    def _epoch():
        sess = DPPSession(spec, t, n_workers=1, tensor_cache=cache)
        out = sess.run_to_completion(timeout_s=60)
        return out, sess.worker_metrics()

    first, m1 = _epoch()
    assert m1.rows_from_cache == 0
    warm, m2 = _epoch()
    assert m2.rows_from_cache == 512          # same generation: cache hit
    t.rewrite_partition(
        0, generate_partition(t.schema, 0,
                              DataGenConfig(rows_per_partition=512, seed=99)),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
    )
    assert t.partitions[0].generation == 1
    post, m3 = _epoch()
    assert m3.rows_from_cache == 0            # new generation: no stale serve
    ref = sorted(float(np.nan_to_num(b["dense"]).sum()) for b in post)
    stale = sorted(float(np.nan_to_num(b["dense"]).sum()) for b in warm)
    assert ref != stale                       # content actually changed


def test_session_with_prefetch_serves_identical_batches(lockdep):
    # under the lock-order sanitizer: this path exercises the widest lock
    # interplay in the repo (master lease lock, worker buffers, stripe
    # cache, prefetch planner, tectonic mutate/stats locks) concurrently
    from repro.core.cache import StripeCache
    from repro.core.dpp import DPPService

    wh, batches_ref = None, None
    results = {}
    for prefetch in (False, True):
        s = make_schema("pfs", 20, 6, seed=0)
        wh = Warehouse()
        t = wh.create_table(s)
        t.generate(2, DataGenConfig(rows_per_partition=1024, seed=1),
                   dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
        svc = DPPService(wh, stripe_cache=StripeCache())
        sess = svc.create_session("j", _spec(t), n_workers=2, prefetch=prefetch)
        out = sess.run_to_completion(timeout_s=60)
        results[prefetch] = sorted(
            float(np.nan_to_num(b["dense"]).sum()) for b in out
        )
        total = sum(b["label"].shape[0] for b in out)
        assert total == 2 * 1024
        if prefetch:
            assert sess.prefetcher.metrics.plans > 0
    assert results[False] == pytest.approx(results[True])
