from typing import Any

from repro.models.common import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    param_count,
)


def build_model(cfg: Any):
    """Model registry: config -> model object."""
    from repro.models.dlrm import DLRM, DLRMConfig

    if isinstance(cfg, DLRMConfig):
        return DLRM(cfg)

    assert isinstance(cfg, ModelConfig), type(cfg)
    if cfg.encoder_layers > 0:
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg)
    if cfg.family == "ssm":
        from repro.models.ssm_lm import SSMLM

        return SSMLM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridLM

        return HybridLM(cfg)
    from repro.models.transformer import DecoderLM

    return DecoderLM(cfg)
