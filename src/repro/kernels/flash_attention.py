"""Pallas TPU kernel: flash attention forward (trainer-side hot op).

Grid (batch*heads, q_blocks, k_blocks); the innermost k dimension is
sequential on TPU, so the online-softmax (m, l, acc) state lives in the
output block + VMEM scratch carried across k steps.  Block sizes default to
MXU-aligned (128, 128).  The XLA custom-VJP path in
``repro.models.attention`` is the portable equivalent used for dry-runs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *, scale, causal, block_q, block_k):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (bq, d)
    k = k_ref[0]                                     # (bk, d)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        out_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,     # (B, H, S, D)
    k: jax.Array,     # (B, H, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    t = k.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, t)
    scale = 1.0 / math.sqrt(d)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    grid = (b * h, pl.cdiv(s, bq), pl.cdiv(t, bk))
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, block_q=bq, block_k=bk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m
            pltpu.VMEM((bq, 1), jnp.float32),    # l
            pltpu.VMEM((bq, d), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
