"""Attention: GQA (chunked online-softmax prefill + decode) and MLA.

The XLA path uses a flash-style blocked attention written with ``lax.scan``
so that 32k-token prefills never materialize (S, S) score matrices.  The
Pallas kernel in ``repro.kernels.flash_attention`` is the TPU fast path; the
functions here are the portable reference used for dry-run lowering.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.common import MLAConfig, ModelConfig, ParamSpec
from repro.models.layers import apply_rope, rmsnorm

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# GQA parameter specs
# ---------------------------------------------------------------------------


def gqa_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None), dt, "scaled"),
        "wk": ParamSpec((d, kvh, hd), ("embed", "kv_heads", None), dt, "scaled"),
        "wv": ParamSpec((d, kvh, hd), ("embed", "kv_heads", None), dt, "scaled"),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed"), dt, "scaled"),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", None), dt, "zeros")
        specs["bk"] = ParamSpec((kvh, hd), ("kv_heads", None), dt, "zeros")
        specs["bv"] = ParamSpec((kvh, hd), ("kv_heads", None), dt, "zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), jnp.float32, "ones")
        specs["k_norm"] = ParamSpec((hd,), (None,), jnp.float32, "ones")
    return specs


def gqa_project_qkv(
    params: Dict[str, jax.Array],
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = constrain(
        jnp.einsum("bsd,dhk->bshk", x, params["wq"]), ("batch", "seq", "heads", None)
    )
    k = constrain(
        jnp.einsum("bsd,dhk->bshk", x, params["wk"]), ("batch", "seq", "kv_heads", None)
    )
    v = constrain(
        jnp.einsum("bsd,dhk->bshk", x, params["wv"]), ("batch", "seq", "kv_heads", None)
    )
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, params["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (prefill / train)
# ---------------------------------------------------------------------------


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    chunk: int = 1024,
    k_chunk: Optional[int] = None,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, T, KVH, D).  Returns (B, S, H, D).

    Flash-style blocked attention with a custom VJP: the forward saves only
    (q, k, v, out, L); the backward recomputes scores block-by-block.  This
    keeps both forward and backward memory at O(S * chunk) — without it,
    differentiating through the block scans stores the full S x S score
    tensor per layer (measured: 8.6 GB/layer at 4k, fatal at 32k).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)

    cq = min(chunk, s)
    ck = min(k_chunk or chunk, t)
    if s % cq or t % ck:
        return _dense_attention(q, k, v, causal=causal, scale=scale)

    # GQA: expand kv to full query heads ONCE (outside the chunk loops) so
    # the head dim stays a clean TP-shardable axis.  Under SPMD each shard
    # materializes only its own g copies, and the backward reduction over
    # the group dim happens once per layer instead of once per chunk.
    if g > 1:
        k = constrain(jnp.repeat(k, g, axis=2), ("batch", "seq", "heads", None))
        v = constrain(jnp.repeat(v, g, axis=2), ("batch", "seq", "heads", None))
    return _flash(q, k, v, causal, cq, ck, scale)



@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, cq, ck, scale):
    out, _ = _flash_fwd_impl(q, k, v, causal, cq, ck, scale)
    return out


def _flash_fwd_impl(q, k, v, causal, cq, ck, scale):
    b, s, h, d = q.shape
    t = k.shape[1]
    nq, nk = s // cq, t // ck
    qb = q.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)          # (nq,B,H,Cq,D)
    qb = constrain(qb, (None, "batch", "heads", None, None))
    kb = k.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)          # (nk,B,H,Ck,D)
    vb = v.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)
    q_pos = jnp.arange(cq)
    k_pos = jnp.arange(ck)

    def q_block(_, qi_and_q):
        qi, qc = qi_and_q
        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, d), jnp.float32)

        def k_block(carry, inp):
            m, l, acc = carry
            kj, kc, vc = inp
            sc = jnp.einsum("bhqd,bhkd->bhqk", qc, kc).astype(jnp.float32) * scale
            if causal:
                mask = (qi * cq + q_pos)[:, None] >= (kj * ck + k_pos)[None, :]
                sc = jnp.where(mask, sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(qc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(k_block, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))                       # (B,H,Cq)
        return None, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)            # (B,S,H,D)
    lse = lses.transpose(1, 2, 0, 3).reshape(b, h, s)                  # (B,H,S)
    return out, lse


def _flash_fwd(q, k, v, causal, cq, ck, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, cq, ck, scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, cq, ck, scale, res, dout):
    q, k, v, out, lse = res
    b, s, h, d = q.shape
    t = k.shape[1]
    nq, nk = s // cq, t // ck

    # row-wise D = sum(dout * out)
    delta = jnp.einsum("bshd,bshd->bhs", dout.astype(jnp.float32), out.astype(jnp.float32))

    qb = q.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)           # (nq,B,H,Cq,D)
    dob = dout.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)
    lseb = lse.reshape(b, h, nq, cq).transpose(2, 0, 1, 3)             # (nq,B,H,Cq)
    deltab = delta.reshape(b, h, nq, cq).transpose(2, 0, 1, 3)
    kb = k.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)           # (nk,B,H,Ck,D)
    vb = v.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)
    q_pos = jnp.arange(cq)
    k_pos = jnp.arange(ck)

    def kv_block(dq_acc, inp):
        kj, kc, vc = inp
        dk0 = jnp.zeros((b, h, ck, d), jnp.float32)
        dv0 = jnp.zeros((b, h, ck, d), jnp.float32)

        def q_block(carry, qinp):
            dk, dv = carry
            qi, qc, doc, lc, Dc = qinp
            sc = jnp.einsum("bhqd,bhkd->bhqk", qc, kc).astype(jnp.float32) * scale
            if causal:
                mask = (qi * cq + q_pos)[:, None] >= (kj * ck + k_pos)[None, :]
                sc = jnp.where(mask, sc, NEG_INF)
            p = jnp.exp(sc - lc[..., None])                             # (B,H,Cq,Ck)
            dv = dv + jnp.einsum("bhqk,bhqd->bhkd", p.astype(doc.dtype), doc)
            dp = jnp.einsum("bhqd,bhkd->bhqk", doc, vc).astype(jnp.float32)
            ds = p * (dp - Dc[..., None]) * scale                       # (B,H,Cq,Ck)
            ds = ds.astype(qc.dtype)
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", ds, kc)
            dk = dk + jnp.einsum("bhqk,bhqd->bhkd", ds, qc)
            return (dk, dv), dq_i

        (dk, dv), dq_blocks = jax.lax.scan(
            q_block, (dk0, dv0), (jnp.arange(nq), qb, dob, lseb, deltab)
        )
        dq_acc = dq_acc + dq_blocks                                     # (nq,B,H,Cq,D)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((nq, b, h, cq, d), jnp.float32)
    dq_blocks, (dks, dvs) = jax.lax.scan(kv_block, dq0, (jnp.arange(nk), kb, vb))
    dq = dq_blocks.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d).astype(q.dtype)
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(b, t, h, d).astype(k.dtype)
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(b, t, h, d).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def _dense_attention(q, k, v, *, causal: bool, scale: float) -> jax.Array:
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(t)[None, :]
        sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, s, h, d)


# ---------------------------------------------------------------------------
# Decode attention over a KV cache
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,           # (B, 1, H, D)
    k_cache: jax.Array,     # (B, S, KVH, D)
    v_cache: jax.Array,
    pos: jax.Array,         # scalar int32: current length (number of valid kv)
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    b, _, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, kvh, g, d)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    sc = constrain(sc, ("batch", None, None, "kv_seq"))
    valid = jnp.arange(s)[None, None, None, :] <= pos
    sc = jnp.where(valid, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache)
    return out.reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.mla
    assert m is not None
    d, h = cfg.d_model, cfg.num_heads
    dt = cfg.param_dtype
    return {
        "w_dq": ParamSpec((d, m.q_lora_rank), ("embed", None), dt, "scaled"),
        "q_norm": ParamSpec((m.q_lora_rank,), (None,), jnp.float32, "ones"),
        "w_uq": ParamSpec(
            (m.q_lora_rank, h, m.qk_nope_dim + m.qk_rope_dim),
            (None, "heads", None), dt, "scaled",
        ),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", None), dt, "scaled"),
        "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), jnp.float32, "ones"),
        "w_uk": ParamSpec((m.kv_lora_rank, h, m.qk_nope_dim), (None, "heads", None), dt, "scaled"),
        "w_uv": ParamSpec((m.kv_lora_rank, h, m.v_head_dim), (None, "heads", None), dt, "scaled"),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", None, "embed"), dt, "scaled"),
    }


def mla_compress(params, x, positions, cfg: ModelConfig):
    """Project hidden states to the compressed KV cache entries.

    Returns c_kv (B, S, kv_lora) and k_rope (B, S, rope_dim) — exactly what
    is cached for decode (the paper-faithful MLA memory saving).
    """
    m = cfg.mla
    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv, k_rope = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.rms_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_queries(params, x, positions, cfg: ModelConfig):
    m = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
    cq = rmsnorm(cq, params["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_prefill_attention(params, x, positions, cfg: ModelConfig, chunk: int):
    """Full MLA attention by expanding compressed KV into per-head K/V."""
    m = cfg.mla
    q_nope, q_rope = mla_queries(params, x, positions, cfg)
    c_kv, k_rope = mla_compress(params, x, positions, cfg)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    # concatenate nope+rope parts; rope part is shared across heads
    h = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[..., None, :], k_rope.shape[:2] + (h, m.qk_rope_dim))
    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), ("batch", "seq", "heads", None))
    k = constrain(jnp.concatenate([k_nope, k_rope_h], axis=-1), ("batch", "seq", "heads", None))
    v = constrain(v, ("batch", "seq", "heads", None))
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    # v head dim may differ from qk dim; pad v to qk dim for the shared
    # blocked kernel, then slice back.
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    if m.v_head_dim < qk_dim:
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    else:
        v_pad = v
    out = blocked_attention(q, k, v_pad, causal=True, chunk=chunk,
                            k_chunk=4 * chunk, softmax_scale=scale)
    out = out[..., : m.v_head_dim]
    ctx = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return ctx, (c_kv, k_rope)


def mla_decode_attention(params, x, pos, c_kv_cache, k_rope_cache, cfg: ModelConfig):
    """Absorbed-matrix MLA decode: attention runs in the compressed space.

    c_kv_cache: (B, S, kv_lora); k_rope_cache: (B, S, rope_dim).
    """
    m = cfg.mla
    positions = jnp.broadcast_to(pos, x.shape[:2])
    q_nope, q_rope = mla_queries(params, x, positions, cfg)     # (B,1,H,*)
    # absorb W_UK: q_c[h] = q_nope[h] @ W_UK[h]^T  -> compressed-space query
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])  # (B,1,H,kv_lora)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    sc = (
        jnp.einsum("bshr,btr->bhst", q_c, c_kv_cache)
        + jnp.einsum("bshk,btk->bhst", q_rope, k_rope_cache)
    ).astype(jnp.float32) * scale
    valid = jnp.arange(c_kv_cache.shape[1])[None, None, None, :] <= pos
    sc = jnp.where(valid, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    ctx_c = jnp.einsum("bhst,btr->bshr", p, c_kv_cache)          # compressed ctx
    ctx = jnp.einsum("bshr,rhk->bshk", ctx_c, params["w_uv"])    # expand with W_UV
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])
