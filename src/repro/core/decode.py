"""Pluggable DecodeEngine: batched Pallas execution of stripe decode.

Table 9 (§6.3) splits DPP preprocessing into extract (decrypt +
decompress + column decode), transform, and load; PR 5 fused transform,
this module fuses extract's decode half.  Mirrors the TransformEngine
pattern (``repro.core.engine``):

  * ``NumpyDecodeEngine`` — the per-stream reference: exactly the
    behavior ``dwrf.decode_stripe_features`` implements (one XOR pass,
    one decompress, and one unpack/scatter/gather per stream/feature),
    extracted here so each stage is timed and each per-feature numpy
    call is accounted as one kernel launch.
  * ``PallasDecodeEngine`` — batches all streams of a stripe into the
    fused kernels of ``repro.kernels.decode``: ONE launch XOR-decrypts
    every stream's concatenated bytes, ONE launch unpacks every dense
    feature's presence bitmap and scatters its values (features-major
    packing, NaN bits for absent rows), and ONE ragged gather pulls
    every sparse/map array region out of the concatenated payload
    buffer.  Compressed payloads still decompress on host through the
    codec registry — the kernels take over post-decompress.

Both engines produce **byte-identical** ``ColumnBatch``es: the dense
kernel computes entirely in the int32 bit domain (NaN and subnormal
payload values round-trip exactly), the gather kernel is pure byte
movement, and any stream the kernels cannot express bit-exactly —
unexpected payload dtypes, zero-row stripes, labels, malformed presence
bitmaps — is *demoted* to the per-stream reference at run time, so
TensorCache entries stay engine-agnostic.  The differential suite
(``tests/test_decode.py``) pins the parity on the adversarial matrix.

``DecodeStats`` feeds ``WorkerMetrics`` (``extract_fused_s`` /
``extract_fallback_s`` / ``decode_launches``) and carries a
Table-9-style stage split (decrypt / decode / gather / assemble) for
``benchmarks/bench_extract.py``.
"""
from __future__ import annotations

import dataclasses
import struct
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import dwrf
from repro.core.schema import ColumnBatch, SparseColumn
from repro.obs import counter

_U8 = np.dtype(np.uint8)
_I8 = np.dtype("<i8")
_F4 = np.dtype("<f4")


@dataclasses.dataclass
class DecodeStats:
    """Cumulative per-engine accounting (mirrored into ``WorkerMetrics``)."""

    fused_streams: int = counter()     # streams served by the batched kernels
    fallback_streams: int = counter()  # streams decoded per-stream on host
    demoted_streams: int = counter()   # kernel-eligible streams demoted at run time
    kernel_launches: int = counter()   # fused launches + per-feature host calls
    fused_s: float = counter(0.0)      # extract_s attribution: batched path
    fallback_s: float = counter(0.0)   # extract_s attribution: per-stream path
    # Table-9-style stage split (§6.3): the four sum to ~total decode time
    decrypt_s: float = counter(0.0)    # XOR byte pass
    decode_s: float = counter(0.0)     # decompress + header parse + dense unpack
    gather_s: float = counter(0.0)     # sparse/map array extraction
    assemble_s: float = counter(0.0)   # ColumnBatch construction


def _popcount_prefix(packed: np.ndarray, rows: int) -> int:
    """Popcount of the first ``rows`` presence bits (packbits MSB-first).
    ``int.bit_count`` over the whole prefix is faster than any per-byte
    numpy table walk at the sub-KB sizes presence bitmaps have."""
    full, rem = divmod(rows, 8)
    n = int.from_bytes(packed[:full].tobytes(), "little").bit_count()
    if rem:
        n += (int(packed[full]) & ((0xFF00 >> rem) & 0xFF)).bit_count()
    return n


def _decode_payload(
    kind: str,
    fid: int,
    payload: bytes,
    num_rows: int,
    want: set,
    dense: Dict[int, np.ndarray],
    sparse: Dict[int, SparseColumn],
) -> Tuple[Optional[np.ndarray], int]:
    """Reference per-stream payload decode (the extracted current
    behavior of ``dwrf.decode_stripe_features``).  Returns (labels or
    None, number of per-feature decode calls) — the second drives the
    per-feature launch accounting of the numpy dispatch regime."""
    if kind == "dense":
        if fid in want:
            dense[fid] = dwrf._dense_unpayload(payload, num_rows)
            return None, 1
        return None, 0
    if kind == "sparse":
        if fid in want:
            sparse[fid] = dwrf._sparse_unpayload(payload)
            return None, 1
        return None, 0
    if kind == "labels":
        return dwrf._unpack_arrays(payload)[0].astype(np.float32), 1
    if kind == "dense_map":
        arrays = dwrf._unpack_arrays(payload)
        fids = arrays[0].astype(np.int64)
        n = 0
        for i, f in enumerate(fids):
            if f in want:
                dense[int(f)] = arrays[1 + i].astype(np.float32)
                n += 1
        return None, n
    if kind == "sparse_map":
        arrays = dwrf._unpack_arrays(payload)
        fids, flags, base = dwrf.sparse_map_layout(arrays)
        n = 0
        for i, f in enumerate(fids):
            off = arrays[base + 3 * i].astype(np.int64)
            val = arrays[base + 1 + 3 * i].astype(np.int64)
            sc = arrays[base + 2 + 3 * i]
            has_scores = bool(flags[i]) if flags is not None else len(sc) > 0
            if f in want:
                sparse[int(f)] = SparseColumn(
                    offsets=off,
                    values=val,
                    scores=sc.astype(np.float32) if has_scores else None,
                )
                n += 1
        return None, n
    return None, 0      # unknown stream kind: ignored, like the reference


class DecodeEngine:
    """Decodes one stripe's fetched stream bytes into a ``ColumnBatch``."""

    name = "base"

    def __init__(self):
        self.stats = DecodeStats()

    def decode_stripe(
        self,
        stripe: dwrf.StripeInfo,
        fetch: Dict[Tuple[int, str], bytes],
        feature_ids: Sequence[int],
    ) -> ColumnBatch:
        raise NotImplementedError

    def __call__(self, stripe, fetch, feature_ids) -> ColumnBatch:
        return self.decode_stripe(stripe, fetch, feature_ids)


class NumpyDecodeEngine(DecodeEngine):
    """Per-stream reference decode — one XOR pass + one decompress per
    stream and one unpack/scatter/gather per feature, each accounted as
    one kernel launch (the per-feature dispatch regime of §7.2, applied
    to the extract stage)."""

    name = "numpy"

    def decode_stripe(self, stripe, fetch, feature_ids) -> ColumnBatch:
        st = self.stats
        dense: Dict[int, np.ndarray] = {}
        sparse: Dict[int, SparseColumn] = {}
        labels = None
        want = set(feature_ids)
        for s in stripe.streams:
            key = (s.fid, s.kind)
            if key not in fetch:
                continue
            t0 = time.perf_counter()
            codec, body = dwrf.split_stream(fetch[key])
            plain = dwrf._decrypt(body)
            t1 = time.perf_counter()
            payload = codec.decompress(plain)
            t2 = time.perf_counter()
            lab, n_feats = _decode_payload(
                s.kind, s.fid, payload, stripe.num_rows, want, dense, sparse
            )
            if lab is not None:
                labels = lab
            t3 = time.perf_counter()
            st.decrypt_s += t1 - t0
            if s.kind in ("sparse", "sparse_map"):
                st.decode_s += t2 - t1
                st.gather_s += t3 - t2
            else:
                st.decode_s += t3 - t1
            st.fallback_s += t3 - t0
            st.fallback_streams += 1
            st.kernel_launches += 1 + n_feats
        t4 = time.perf_counter()
        batch = ColumnBatch(
            num_rows=stripe.num_rows, dense=dense, sparse=sparse, labels=labels
        )
        st.assemble_s += time.perf_counter() - t4
        return batch


class PallasDecodeEngine(DecodeEngine):
    """Whole-stripe batched decode via ``kernels.decode``.

    ``use_pallas`` follows the ``repro.kernels`` dispatch contract:
    ``None`` (default) runs the compiled Pallas kernels on TPU and the
    XLA-compiled jnp oracles elsewhere — the fast fused path for
    whatever backend is present; ``True`` always runs the Pallas kernels
    (compiled on TPU, **interpret mode** off-TPU — how the differential
    suite validates them on CPU).  All paths compute identical bits, so
    the engine stays byte-compatible with ``NumpyDecodeEngine``.
    """

    name = "pallas"

    def __init__(self, use_pallas: Optional[bool] = None):
        super().__init__()
        self.use_pallas = use_pallas

    # -- fused launches -----------------------------------------------------

    def _xor(self, buf: np.ndarray, n: int) -> np.ndarray:
        """One fused decrypt launch over the stripe's concatenated stream
        bytes.  ``buf`` is already padded to whole int32 tiles (byte-wise
        XOR is position-local, so the word view is exact); the return is a
        zero-copy uint8 *view* of the kernel output truncated to the real
        ``n`` bytes — every downstream consumer (codec ``decompress``,
        ``packed_array_headers``, ``np.frombuffer``) takes any buffer."""
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        words = buf.view("<i4").reshape(-1, 128)
        out = kops.xor_decrypt(jnp.asarray(words), use_pallas=self.use_pallas)
        self.stats.kernel_launches += 1
        return np.asarray(out).reshape(-1).view(np.uint8)[:n]

    def _dense_launch(
        self, rows: int, bm: np.ndarray, vals_list: List[np.ndarray]
    ) -> List[np.ndarray]:
        """One launch for every dense feature: features-major bitmap words
        (``bm``, already packed (F, 4*words) uint8 by the caller) + value
        bit patterns in, f32 bits (NaN where absent) out."""
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        cap = max(max(len(v) for v in vals_list), 1)
        vals = np.zeros((len(vals_list), cap), np.int32)
        for j, v in enumerate(vals_list):
            vals[j, : len(v)] = v
        out = kops.dense_unpack(
            jnp.asarray(bm.view("<i4")), jnp.asarray(vals),
            use_pallas=self.use_pallas,
        )
        self.stats.kernel_launches += 1
        res = np.asarray(out)
        return [res[j, :rows].view(np.float32) for j in range(len(vals_list))]

    def _gather_launch(
        self,
        pool: List[bytes],
        requests: List[Tuple[int, np.dtype, int, int]],
    ) -> List[np.ndarray]:
        """One launch for every requested array region: splice the
        byte-unaligned regions out of the concatenated payload words."""
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        base = np.zeros(len(pool), np.int64)
        pos = 0
        for i, b in enumerate(pool):
            base[i] = pos
            pos += len(b)
        nwords = np.array([-(-nb // 4) for _, _, _, nb in requests], np.int64)
        slots = (nwords + 1) & ~1         # even word slots: 8-byte alignment
        out_off = np.zeros(len(requests) + 1, np.int64)
        np.cumsum(slots, out=out_off[1:])
        total = int(out_off[-1])

        s_rows = max(-(-(-(-pos // 4) + 2) // 128), 1)
        src = np.zeros(s_rows * 512, np.uint8)
        at = 0
        for b in pool:
            src[at: at + len(b)] = (
                b if isinstance(b, np.ndarray) else np.frombuffer(b, np.uint8)
            )
            at += len(b)
        m_rows = max(-(-total // 128), 1)
        idx = np.zeros(m_rows * 128, np.int32)
        shift = np.zeros(m_rows * 128, np.int32)
        if total:
            # vectorized per-lane index build: lane r of request q reads
            # source word start[q]+r with the request's constant bit shift
            ab = base[[pi for pi, _, _, _ in requests]] \
                + np.array([off for _, _, off, _ in requests], np.int64)
            req = np.repeat(np.arange(len(requests)), slots)
            lane = (np.arange(total, dtype=np.int64)
                    - np.repeat(out_off[:-1], slots))
            idx[:total] = ((ab // 4)[req] + lane).astype(np.int32)
            shift[:total] = ((ab % 4) * 8)[req].astype(np.int32)
        out = kops.ragged_gather(
            jnp.asarray(src.view("<i4").reshape(s_rows, 128)),
            jnp.asarray(idx.reshape(m_rows, 128)),
            jnp.asarray(shift.reshape(m_rows, 128)),
            use_pallas=self.use_pallas,
        )
        self.stats.kernel_launches += 1
        flat = np.ascontiguousarray(np.asarray(out).reshape(-1))
        return [
            np.frombuffer(flat, dt, nb // dt.itemsize, int(out_off[r]) * 4)
            for r, (_, dt, _, nb) in enumerate(requests)
        ]

    # -- stripe decode ------------------------------------------------------

    def decode_stripe(self, stripe, fetch, feature_ids) -> ColumnBatch:
        st = self.stats
        rows = stripe.num_rows
        want = set(feature_ids)
        dense: Dict[int, np.ndarray] = {}
        sparse: Dict[int, SparseColumn] = {}
        labels = None

        # phase 1 — one fused XOR pass over every fetched stream's bytes.
        # Whole streams (codec byte included) go into one preallocated
        # padded buffer so the stripe's bytes are copied exactly once; the
        # codec byte is read from the *original* buffer and its decrypted
        # garbage twin in ``plain`` is simply never referenced (XOR is
        # byte-position-local, so everything after it decrypts exactly).
        t0 = time.perf_counter()
        codecs = dwrf._CODECS
        entries: List[Tuple[dwrf.StreamInfo, dwrf.Codec, int, int]] = []
        parts: List[bytes] = []
        pos = 0
        for s in stripe.streams:
            key = (s.fid, s.kind)
            data = fetch.get(key)
            if data is None:
                continue
            codec = codecs.get(data[0])
            if codec is None:
                dwrf.split_stream(data)      # raises the reference KeyError
            entries.append((s, codec, pos + 1, len(data) - 1))
            parts.append(data)
            pos += len(data)
        if not entries:
            return ColumnBatch(num_rows=rows, dense={}, sparse={}, labels=None)
        buf = np.zeros(pos + (-pos) % 512, np.uint8)
        mv = memoryview(buf)                 # C-level memcpy per stream
        at = 0
        for d in parts:
            ln = len(d)
            mv[at: at + ln] = d
            at += ln
        plain = self._xor(buf, pos)
        t1 = time.perf_counter()
        st.decrypt_s += t1 - t0
        st.fused_s += t1 - t0

        # phase 2 — host decompress + header parse + classification.
        # ``tokens`` records, in stream order, which fids each stream
        # contributes and through which path: the reference inserts dict
        # keys in stream order, so assembly must replay that order even
        # when fused and demoted streams interleave.
        #   ["f", dense_fids, sparse_fids]  — fused stream
        #   ["h", host_job_index]           — host-fallback stream
        dense_jobs: List[list] = []   # [fid, packed, vals, payload, s, tok, oi]
        pool: List[bytes] = []
        requests: List[Tuple[int, np.dtype, int, int]] = []
        dense_sinks: List[Tuple[int, int]] = []              # (fid, req)
        sparse_sinks: List[Tuple[int, int, int, Optional[int]]] = []
        host_jobs: List[list] = []           # [stream_order, StreamInfo, payload]
        tokens: List[list] = []

        def _req(pi: int, hdr: Tuple[np.dtype, int, int]) -> int:
            requests.append((pi, hdr[0], hdr[1], hdr[2]))
            return len(requests) - 1

        t2 = time.perf_counter()
        headers = dwrf.packed_array_headers
        fro = np.frombuffer

        # vectorized prepass: flattened dense streams under the raw codec
        # share one fixed ``_pack_arrays`` header template (only the
        # value-byte count differs), so template match, length check, and
        # presence-bitmap extraction run as whole-stripe numpy gathers
        # instead of per-stream header walks.  Anything that misses the
        # template falls through to the generic per-stream classification
        # below — same decision, slower route.
        fast: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        if rows > 0:
            nb1 = -(-rows // 8)
            head = struct.pack("<II", 2, 3) + b"|u1" + struct.pack("<Q", nb1)
            mid = struct.pack("<I", 3) + b"<f4"
            cands = [
                (oi, start, ln)
                for oi, (s, codec, start, ln) in enumerate(entries)
                if s.kind == "dense" and codec.cid == 0
                and s.fid in want and ln >= 34 + nb1
            ]
            if cands:
                starts = np.array([c[1] for c in cands], np.int64)
                lns = np.array([c[2] for c in cands], np.int64)
                okh = (plain[starts[:, None] + np.arange(19)]
                       == fro(head, np.uint8)).all(1)
                okm = (plain[starts[:, None] + (19 + nb1) + np.arange(7)]
                       == fro(mid, np.uint8)).all(1)
                nb2 = np.ascontiguousarray(
                    plain[starts[:, None] + (26 + nb1) + np.arange(8)]
                ).view("<u8")[:, 0].astype(np.int64)
                ok = okh & okm & (nb2 % 4 == 0) & (lns == 34 + nb1 + nb2)
                if ok.any():
                    sel = np.flatnonzero(ok)
                    bmat = plain[starts[sel, None] + 19 + np.arange(nb1)]
                    for k, ci in enumerate(sel):
                        oi, s0, _ = cands[int(ci)]
                        fast[oi] = (
                            bmat[k],
                            fro(plain, "<i4", int(nb2[ci]) // 4,
                                s0 + 34 + nb1),
                        )

        for oi, (s, codec, start, ln) in enumerate(entries):
            payload = plain[start: start + ln]
            if codec.cid:                     # raw (cid 0) is the identity
                payload = codec.decompress(payload)
            demote = False
            if s.kind == "dense" and s.fid in want:
                fv = fast.get(oi)
                if fv is not None:
                    dense_jobs.append([
                        s.fid, fv[0], fv[1], payload, s, len(tokens), oi,
                    ])
                    tokens.append(["f", [s.fid], ()])
                    continue
                ok = rows > 0
                if ok:
                    hdrs = headers(payload)
                    ok = (
                        len(hdrs) == 2
                        and hdrs[0][0] == _U8 and hdrs[1][0] == _F4
                        and hdrs[1][2] % 4 == 0
                        and hdrs[0][2] * 8 >= rows
                    )
                if ok:
                    # the presence-popcount == len(values) precondition of
                    # the reference scatter is validated vectorized across
                    # all dense jobs after this loop
                    dense_jobs.append([
                        s.fid,
                        fro(payload, np.uint8, hdrs[0][2], hdrs[0][1]),
                        fro(payload, "<i4", hdrs[1][2] // 4, hdrs[1][1]),
                        payload, s, len(tokens), oi,
                    ])
                    tokens.append(["f", [s.fid], ()])
                else:
                    demote = True
            elif s.kind == "sparse" and s.fid in want:
                hdrs = headers(payload)
                ok = (
                    len(hdrs) in (2, 3)
                    and hdrs[0][0] == _I8 and hdrs[1][0] == _I8
                    and (len(hdrs) == 2 or hdrs[2][0] == _F4)
                )
                if ok:
                    pi = len(pool)
                    pool.append(payload)
                    sparse_sinks.append((
                        s.fid, _req(pi, hdrs[0]), _req(pi, hdrs[1]),
                        _req(pi, hdrs[2]) if len(hdrs) == 3 else None,
                    ))
                    tokens.append(["f", (), [s.fid]])
                    st.fused_streams += 1
                else:
                    demote = True
            elif s.kind == "dense_map":
                hdrs = headers(payload)
                ok = len(hdrs) >= 1 and hdrs[0][0] == _I8
                if ok:
                    fids = fro(payload, _I8, hdrs[0][2] // 8, hdrs[0][1])
                    wanted = [
                        (i, int(f)) for i, f in enumerate(fids) if f in want
                    ]
                    ok = len(hdrs) == 1 + len(fids) and all(
                        hdrs[1 + i][0] == _F4 for i, _ in wanted
                    )
                if ok:
                    pi = len(pool)
                    pool.append(payload)
                    for i, f in wanted:
                        dense_sinks.append((f, _req(pi, hdrs[1 + i])))
                    tokens.append(["f", [f for _, f in wanted], ()])
                    st.fused_streams += 1
                else:
                    demote = True
            elif s.kind == "sparse_map":
                hdrs = headers(payload)

                def _meta(i: int) -> np.ndarray:
                    dt, off, nb = hdrs[i]
                    return fro(payload, dt, nb // dt.itemsize, off)

                ok = len(hdrs) >= 1
                wanted = []
                flags = None
                if ok:
                    a0 = _meta(0)
                    v2 = (
                        a0.size == 1 and a0.dtype.kind == "i"
                        and int(a0[0]) == dwrf.SPARSE_MAP_V2
                    )
                    if v2 and len(hdrs) >= 3:
                        fids, flags, base = _meta(1), _meta(2), 3
                    elif not v2:
                        fids, base = a0, 1
                    else:
                        ok = False
                if ok:
                    ok = len(hdrs) == base + 3 * len(fids)
                if ok:
                    wanted = [
                        (i, int(f)) for i, f in enumerate(fids) if f in want
                    ]
                    ok = all(
                        hdrs[base + 3 * i][0] == _I8
                        and hdrs[base + 1 + 3 * i][0] == _I8
                        and hdrs[base + 2 + 3 * i][0] == _F4
                        for i, _ in wanted
                    )
                if ok:
                    pi = len(pool)
                    pool.append(payload)
                    for i, f in wanted:
                        has_scores = (
                            bool(flags[i]) if flags is not None
                            else hdrs[base + 2 + 3 * i][2] > 0
                        )
                        sparse_sinks.append((
                            f,
                            _req(pi, hdrs[base + 3 * i]),
                            _req(pi, hdrs[base + 1 + 3 * i]),
                            _req(pi, hdrs[base + 2 + 3 * i])
                            if has_scores else None,
                        ))
                    tokens.append(["f", (), [f for _, f in wanted]])
                    st.fused_streams += 1
                else:
                    demote = True
            elif s.kind == "labels":
                tokens.append(["h", len(host_jobs)])
                host_jobs.append([oi, s, payload])
                continue
            else:
                # unwanted flattened streams / unknown kinds: decompressed
                # (like the reference) with nothing left to batch
                st.fused_streams += 1
                continue
            if demote:
                st.demoted_streams += 1
                tokens.append(["h", len(host_jobs)])
                host_jobs.append([oi, s, payload])

        # vectorized precondition check over all dense jobs: the reference
        # scatter needs popcount(presence[:rows]) == len(values) per
        # feature — violations demote to host, which raises the reference
        # error at that stream's position.  Dense jobs only count toward
        # fused_streams once they survive this check (counters are
        # monotonic; no increment-then-undo).
        if dense_jobs:
            nb = -(-rows // 8)
            nw4 = ((nb + 3) // 4) * 4
            bm = np.zeros((len(dense_jobs), nw4), np.uint8)
            for j, job in enumerate(dense_jobs):
                bm[j, :nb] = job[1][:nb]
            pops = np.unpackbits(bm[:, :nb], axis=1, count=rows).sum(
                axis=1, dtype=np.int64
            )
            bad = [
                j for j, job in enumerate(dense_jobs)
                if int(pops[j]) != len(job[2])
            ]
            if bad:
                for j in bad:
                    fid, _, _, payload, s, ti, oi = dense_jobs[j]
                    st.demoted_streams += 1
                    tokens[ti] = ["h", len(host_jobs)]
                    host_jobs.append([oi, s, payload])
                keep = [
                    j for j in range(len(dense_jobs)) if j not in set(bad)
                ]
                dense_jobs = [dense_jobs[j] for j in keep]
                bm = bm[keep]
            st.fused_streams += len(dense_jobs)
        t3 = time.perf_counter()
        st.decode_s += t3 - t2
        st.fused_s += t3 - t2

        # phase 3 — the two batched launches
        if dense_jobs:
            t4 = time.perf_counter()
            cols = self._dense_launch(rows, bm, [j[2] for j in dense_jobs])
            for job, col in zip(dense_jobs, cols):
                dense[job[0]] = col
            dt = time.perf_counter() - t4
            st.decode_s += dt
            st.fused_s += dt
        if requests:
            t5 = time.perf_counter()
            arrs = self._gather_launch(pool, requests)
            for fid, ri in dense_sinks:
                dense[fid] = arrs[ri]
            for fid, oi, vi, si in sparse_sinks:
                sparse[fid] = SparseColumn(
                    offsets=arrs[oi], values=arrs[vi],
                    scores=arrs[si] if si is not None else None,
                )
            dt = time.perf_counter() - t5
            st.gather_s += dt
            st.fused_s += dt

        # phase 4 — per-stream host fallback (labels + demoted streams),
        # processed in stream order so any reference error raises at the
        # same stream the per-stream path would reach first.  Raw-codec
        # payloads are still views of the decrypt output here; the
        # reference decoder wants real bytes (``io.BytesIO`` reads).
        added: List[Optional[Tuple[List[int], List[int]]]] = \
            [None] * len(host_jobs)
        for ji in sorted(range(len(host_jobs)),
                         key=lambda i: host_jobs[i][0]):
            _, s, payload = host_jobs[ji]
            t6 = time.perf_counter()
            if not isinstance(payload, bytes):
                payload = bytes(payload)
            before_d, before_s = set(dense), set(sparse)
            lab, n_feats = _decode_payload(
                s.kind, s.fid, payload, rows, want, dense, sparse
            )
            if lab is not None:
                labels = lab
            added[ji] = (
                [f for f in dense if f not in before_d],
                [f for f in sparse if f not in before_s],
            )
            dt = time.perf_counter() - t6
            if s.kind in ("sparse", "sparse_map"):
                st.gather_s += dt
            else:
                st.decode_s += dt
            st.fallback_s += dt
            st.fallback_streams += 1
            st.kernel_launches += n_feats

        # phase 5 — assemble by replaying the reference's stream-order
        # dict insertion from the tokens
        t7 = time.perf_counter()
        dense_order: List[int] = []
        sparse_order: List[int] = []
        for tok in tokens:
            if tok[0] == "f":
                dense_order += tok[1]
                sparse_order += tok[2]
            else:
                a = added[tok[1]]
                if a is not None:
                    dense_order += a[0]
                    sparse_order += a[1]
        batch = ColumnBatch(
            num_rows=rows,
            dense={f: dense[f] for f in dense_order if f in dense},
            sparse={f: sparse[f] for f in sparse_order if f in sparse},
            labels=labels,
        )
        dt = time.perf_counter() - t7
        st.assemble_s += dt
        st.fused_s += dt
        return batch


DECODE_ENGINES = {"numpy": NumpyDecodeEngine, "pallas": PallasDecodeEngine}


def make_decode_engine(
    engine: Union[str, DecodeEngine, None],
) -> DecodeEngine:
    """Resolve a decode-engine choice (name, instance, or factory) for one
    exclusive owner (engines accumulate stats; don't share instances
    across readers)."""
    if engine is None:
        return NumpyDecodeEngine()
    if isinstance(engine, DecodeEngine):
        return engine
    if isinstance(engine, str):
        try:
            return DECODE_ENGINES[engine]()
        except KeyError:
            raise ValueError(
                f"unknown decode engine {engine!r}; "
                f"expected one of {sorted(DECODE_ENGINES)}"
            ) from None
    return engine()      # factory callable
