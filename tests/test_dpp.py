import time

import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import AutoScaler, DPPMaster, DPPSession, SessionSpec
from repro.core.schema import make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse


def _table(n_partitions=2, rows=1024):
    s = make_schema("dpt", 20, 6, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(n_partitions, DataGenConfig(rows_per_partition=rows, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    return t


def _spec(t, **kw):
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    d = dict(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=256, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )
    d.update(kw)
    return SessionSpec(**d)


def test_session_one_epoch_exact_batches():
    t = _table()
    sess = DPPSession(_spec(t), t, n_workers=2)
    batches = sess.run_to_completion(timeout_s=60)
    assert len(batches) == 2 * 1024 // 256
    assert batches[0]["dense"].shape == (256, 6)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 1024


def test_worker_failure_restart_completes_epoch():
    t = _table()
    # the ONLY worker dies after 2 splits; the monitor must restart it or the
    # epoch cannot complete
    sess = DPPSession(_spec(t), t, n_workers=1, lease_s=1.0, monitor_interval_s=0.1)
    sess.workers[0].fail_after_splits = 2
    batches = sess.run_to_completion(timeout_s=60)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 1024
    assert len(sess.restart_events) >= 1


def test_master_checkpoint_restore_resumes():
    t = _table()
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows)
    s1 = m.get_split("w0"); m.complete_split("w0", s1.split_id)
    s2 = m.get_split("w0"); m.complete_split("w0", s2.split_id)
    ckpt = m.checkpoint()
    m2 = DPPMaster.restore(ckpt, rows)
    done, total = m2.progress
    assert done == 2
    seen = set()
    while True:
        s = m2.get_split("w1")
        if s is None:
            break
        seen.add(s.split_id)
        m2.complete_split("w1", s.split_id)
    assert s1.split_id not in seen and s2.split_id not in seen
    assert m2.finished


def test_straggler_lease_redispatch():
    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.05)
    s = m.get_split("slow")
    time.sleep(0.1)   # lease expires; straggler mitigation re-dispatches
    s2 = m.get_split("fast")
    assert s2.split_id == s.split_id


def test_forget_worker_releases_leases():
    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=100.0)
    s = m.get_split("dead")
    m.forget_worker("dead")
    s2 = m.get_split("alive")
    assert s2.split_id == s.split_id


def test_autoscaler_decisions():
    a = AutoScaler(max_workers=64)
    assert a.decide(4, buffered_batches=0, mean_cpu_util=0.9, stalls_since_last=3) > 0
    assert a.decide(4, buffered_batches=100, mean_cpu_util=0.1, stalls_since_last=0) < 0
    assert a.decide(4, buffered_batches=10, mean_cpu_util=0.6, stalls_since_last=0) == 0
    # respects max
    assert a.decide(64, buffered_batches=0, mean_cpu_util=1.0, stalls_since_last=5) == 0


def test_autoscaling_session_scales_out():
    t = _table(n_partitions=2, rows=2048)
    sess = DPPSession(_spec(t), t, n_workers=1, auto_scale=True,
                      monitor_interval_s=0.05, max_workers=4)
    batches = sess.run_to_completion(timeout_s=90)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 2048


# -- client satellites (ISSUE 3) ---------------------------------------------


class _StubWorker:
    """Just enough of DPPWorker's serving surface for client unit tests."""

    def __init__(self, batches=()):
        self.alive = True
        self._q = list(batches)

    @property
    def buffered(self):
        return len(self._q)

    def get_batch(self, timeout=0.0):
        return self._q.pop(0) if self._q else None


def test_client_partition_offset_is_stable_digest():
    import zlib

    from repro.core.dpp import DPPClient

    workers = [_StubWorker() for _ in range(8)]
    c = DPPClient("trainer-3", workers)
    # crc32, not hash(): identical across processes whatever PYTHONHASHSEED
    assert c._partition_offset == zlib.crc32(b"trainer-3") % 8


def test_client_stall_accounting_only_on_actual_stall():
    from repro.core.dpp import DPPClient

    w = _StubWorker([{"x": np.zeros(4, np.float32)}])
    c = DPPClient("c0", [w])
    assert c.get_batch(timeout=1.0) is not None
    # batch was available immediately: NO stall time may accrue
    assert c.metrics.stalls == 0
    assert c.metrics.stall_s == 0.0
    # now the buffer is empty and the worker produces nothing
    t0 = time.perf_counter()
    assert c.get_batch(timeout=0.05) is None
    waited = time.perf_counter() - t0
    assert c.metrics.stalls == 1
    assert 0.0 < c.metrics.stall_s <= waited + 0.01


def test_concat_labels_raises_on_mixed_labeling():
    from repro.core.dpp.worker import _concat_labels

    labeled = ({}, np.ones(4, np.float32), 4)
    unlabeled = ({}, None, 4)
    assert _concat_labels([unlabeled, unlabeled]) is None
    np.testing.assert_array_equal(
        _concat_labels([labeled, labeled]), np.ones(8, np.float32)
    )
    with pytest.raises(ValueError, match="mixed labeled/unlabeled"):
        _concat_labels([labeled, unlabeled])


# -- prefetch planner (ISSUE 3) ----------------------------------------------


def test_prefetch_planner_warms_only_uncached_segments():
    from repro.core.cache import StripeCache
    from repro.core.dpp import DPPMaster, PrefetchPlanner

    s = make_schema("pf", 20, 6, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(2, DataGenConfig(rows_per_partition=1024, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    cache = StripeCache()
    wh.attach_cache(cache)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, partition_stripe_rows={p: 256 for p in spec.partitions})

    planner = PrefetchPlanner(t, m, spec.feature_ids, tenant="job", depth=32)
    fetched = planner.prefetch_once()
    assert fetched > 0
    assert planner.metrics.splits_warmed > 0
    # everything upcoming is now cached: a second pass fetches nothing
    planner2 = PrefetchPlanner(t, m, spec.feature_ids, tenant="job", depth=32)
    assert planner2.prefetch_once() == 0
    assert planner2.metrics.bytes_already_cached > 0
    # and the worker read path is served from the cache, byte-identical
    from repro.core.reader import TableReader

    r = TableReader(t, spec.feature_ids, record_popularity=False, tenant="job")
    res = r.read_rows(t.partitions[0], 0, 256)
    assert res.bytes_from_storage == 0
    assert res.bytes_from_cache == res.bytes_read
    # prefetched bytes are charged to the prefetching tenant
    assert cache.tenants["job"].bytes_stored > 0
    # a partition rewrite bumps the generation: its splits become warmable
    # again instead of being skipped forever on stale cached bytes
    from repro.core.datagen import generate_partition

    t.rewrite_partition(
        0, generate_partition(s, 0, DataGenConfig(rows_per_partition=1024, seed=9)),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
    )
    assert planner2.prefetch_once() > 0


def test_session_with_prefetch_serves_identical_batches():
    from repro.core.cache import StripeCache
    from repro.core.dpp import DPPService

    wh, batches_ref = None, None
    results = {}
    for prefetch in (False, True):
        s = make_schema("pfs", 20, 6, seed=0)
        wh = Warehouse()
        t = wh.create_table(s)
        t.generate(2, DataGenConfig(rows_per_partition=1024, seed=1),
                   dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
        svc = DPPService(wh, stripe_cache=StripeCache())
        sess = svc.create_session("j", _spec(t), n_workers=2, prefetch=prefetch)
        out = sess.run_to_completion(timeout_s=60)
        results[prefetch] = sorted(
            float(np.nan_to_num(b["dense"]).sum()) for b in out
        )
        total = sum(b["label"].shape[0] for b in out)
        assert total == 2 * 1024
        if prefetch:
            assert sess.prefetcher.metrics.plans > 0
    assert results[False] == pytest.approx(results[True])
