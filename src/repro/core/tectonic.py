"""Tectonic-like append-only distributed filesystem with a storage-node
performance/power model.

Files are split into fixed-size blocks (8 MB, the paper's chunk size),
replicated 3x across storage nodes.  Reads are served by extents
(offset, length); the node model charges seek + rotational + transfer time
per I/O, which is what makes small coalesced-read experiments (Table 6,
Table 12) reproduce the paper's HDD IOPS cliff.

Media constants follow §7.1/§7.2: HDDs have an ~8x throughput-to-storage
gap; SSD nodes give 326% IOPS/W at 9% capacity/W relative to HDD.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import NULL_TRACER, counter, merge_metrics

BLOCK_BYTES = 8 * 1024 * 1024         # Tectonic chunk size (§7.5)
REPLICATION = 3


@dataclasses.dataclass(frozen=True)
class MediaSpec:
    name: str
    seek_ms: float                    # average positioning latency per I/O
    transfer_MBps: float              # sequential bandwidth
    capacity_TB: float
    power_W: float

    def io_time_s(self, nbytes: int) -> float:
        return self.seek_ms / 1e3 + nbytes / (self.transfer_MBps * 1e6)

    @property
    def max_iops(self) -> float:
        return 1e3 / self.seek_ms


# Calibrated so SSD/HDD IOPS-per-watt = ~3.26x and capacity-per-watt = ~9%
# of HDD (§7.2 figures), with plausible absolute magnitudes.
HDD = MediaSpec(name="hdd", seek_ms=8.0, transfer_MBps=180.0, capacity_TB=18.0, power_W=8.0)
SSD = MediaSpec(name="ssd", seek_ms=0.08, transfer_MBps=2800.0, capacity_TB=3.84, power_W=262.0)


@dataclasses.dataclass
class IOStats:
    num_ios: int = counter()
    bytes_read: int = counter()
    seek_time_s: float = counter(0.0)
    transfer_time_s: float = counter(0.0)
    io_sizes: List[int] = counter(factory=list)

    @property
    def total_time_s(self) -> float:
        return self.seek_time_s + self.transfer_time_s

    def record(self, nbytes: int, media: MediaSpec) -> None:
        self.num_ios += 1
        self.bytes_read += nbytes
        self.seek_time_s += media.seek_ms / 1e3
        self.transfer_time_s += nbytes / (media.transfer_MBps * 1e6)
        self.io_sizes.append(nbytes)

    def merge(self, other: "IOStats") -> None:
        merge_metrics(self, other)

    def percentiles(self) -> Dict[str, float]:
        if not self.io_sizes:
            return {}
        a = np.asarray(self.io_sizes)
        return {
            "mean": float(a.mean()),
            "std": float(a.std()),
            "p5": float(np.percentile(a, 5)),
            "p25": float(np.percentile(a, 25)),
            "p50": float(np.percentile(a, 50)),
            "p75": float(np.percentile(a, 75)),
            "p95": float(np.percentile(a, 95)),
        }

    @property
    def effective_throughput_MBps(self) -> float:
        t = self.total_time_s
        return (self.bytes_read / 1e6 / t) if t > 0 else 0.0


@dataclasses.dataclass
class StorageNode:
    node_id: int
    media: MediaSpec
    used_bytes: int = 0
    stats: IOStats = dataclasses.field(default_factory=IOStats)

    def read(self, nbytes: int) -> None:
        self.stats.record(nbytes, self.media)


@dataclasses.dataclass
class ExtentRead:
    """Extent payloads plus which tier served each byte."""
    blobs: List[bytes]
    storage_bytes: int = 0
    dram_bytes: int = 0
    flash_bytes: int = 0

    @property
    def cache_bytes(self) -> int:
        return self.dram_bytes + self.flash_bytes


@dataclasses.dataclass
class _BlockRef:
    node_ids: Tuple[int, ...]      # replica placements
    data_off: int                  # offset into the file byte string


class TectonicFS:
    """In-memory append-only FS with byte-accurate files + an I/O cost model."""

    def __init__(
        self,
        num_nodes: int = 12,
        media: MediaSpec = HDD,
        seed: int = 0,
        io_latency_scale: float = 0.0,
    ):
        self.nodes = [StorageNode(i, media) for i in range(num_nodes)]
        self.media = media
        # > 0: storage reads sleep io_time_s * scale, so device latency is
        # felt in wall-clock (cache hits stay instant) — what makes the
        # prefetch-overlap benchmark measure real stall reduction
        self.io_latency_scale = io_latency_scale
        self._files: Dict[str, bytes] = {}
        self._blocks: Dict[str, List[_BlockRef]] = {}
        self._rng = np.random.default_rng(seed)
        self.stats = IOStats()
        self.cache = None                  # optional StripeCache (attach_cache)
        self.tracer = NULL_TRACER          # optional span Tracer (attach_tracer)
        # many sessions' worker threads read one fs: keep the fleet/node
        # accounting consistent (the payload path itself is immutable bytes)
        self._stats_lock = threading.Lock()
        # serializes file-table mutation (append/rewrite) against the read
        # path's (data, blocks, generation) snapshot, so a reader never
        # observes the transient popped state mid-rewrite (RLock: rewrite
        # and append re-enter through create)
        self._mutate_lock = threading.RLock()

    def attach_cache(self, cache) -> None:
        """Install a shared ``StripeCache``: subsequent ``read_extents``
        calls are served from it on hit and admit into it on miss."""
        with self._mutate_lock:
            # published under the mutate lock so an in-flight read's
            # (data, blocks, generation) snapshot can never straddle the
            # cache swap
            self.cache = cache

    def attach_tracer(self, tracer) -> None:
        """Install a span ``Tracer``: subsequent extent reads record
        ``storage.read`` / ``cache.fill`` spans and ``cache.hit`` /
        ``cache.miss`` instants, labeled with tenant/path/tier/bytes."""
        with self._mutate_lock:
            self.tracer = tracer

    # -- write path ---------------------------------------------------------

    def create(self, path: str, data: bytes) -> None:
        with self._mutate_lock:
            assert path not in self._files, f"append-only: {path} exists"
            refs = []
            for off in range(0, max(len(data), 1), BLOCK_BYTES):
                nodes = tuple(
                    int(i) for i in self._rng.choice(len(self.nodes), REPLICATION, replace=False)
                )
                refs.append(_BlockRef(node_ids=nodes, data_off=off))
                for nid in nodes:
                    self.nodes[nid].used_bytes += min(BLOCK_BYTES, len(data) - off)
            # publish blocks before bytes: a reader snapshots both under
            # _mutate_lock, so it never sees one without the other
            self._blocks[path] = refs
            self._files[path] = data

    def _release_placement_locked(self, path: str) -> None:
        """Drop a file's block placement and cached stripes before its
        bytes change; otherwise per-node used_bytes double-counts and the
        cache can serve stale data."""
        base = self._files.get(path, b"")
        for ref in self._blocks.get(path, ()):
            nbytes = min(BLOCK_BYTES, len(base) - ref.data_off)
            for nid in ref.node_ids:
                self.nodes[nid].used_bytes -= nbytes
        self._files.pop(path, None)
        self._blocks.pop(path, None)
        if self.cache is not None:
            self.cache.invalidate_path(path)

    def append(self, path: str, data: bytes) -> None:
        with self._mutate_lock:
            base = self._files.get(path, b"")
            self._release_placement_locked(path)
            self.create(path, base + data)

    def rewrite(self, path: str, data: bytes) -> None:
        """Replace a file's bytes in place (partition churn: the §4
        feature-engineering pipelines continuously rewrite partitions).
        Invalidates the path in the attached cache — dropping its
        path-addressed entries and bumping its dedup generation — before
        the new bytes land, so no reader can be served the old content."""
        with self._mutate_lock:
            assert path in self._files, f"rewrite of non-existent file: {path}"
            self._release_placement_locked(path)
            self.create(path, data)

    def exists(self, path: str) -> bool:
        return path in self._files

    def peek(self, path: str) -> bytes:
        """Accounting-free access to a file's bytes (write-side plumbing,
        e.g. dedup registration) — never use on the training read path."""
        return self._files[path]

    def size(self, path: str) -> int:
        return len(self._files[path])

    def list(self) -> List[str]:
        return sorted(self._files)

    @property
    def used_bytes(self) -> int:
        return sum(len(d) for d in self._files.values())

    # -- read path ----------------------------------------------------------

    def _simulate_latency(self, media: MediaSpec, nbytes: int) -> None:
        if self.io_latency_scale > 0:
            time.sleep(media.io_time_s(nbytes) * self.io_latency_scale)

    def read_extents(
        self, path: str, extents: Sequence[Tuple[int, int]]
    ) -> List[bytes]:
        """Read (offset, length) extents; each extent is one I/O charged to
        the primary replica node of its first block."""
        return self.read_extents_ex(path, extents).blobs

    def read_extents_ex(
        self,
        path: str,
        extents: Sequence[Tuple[int, int]],
        tenant: Optional[str] = None,
    ) -> "ExtentRead":
        """``read_extents`` plus per-source accounting.  With a cache
        attached, each extent is first resolved (content-addressed where the
        dedup index knows the stripe) and looked up; only misses touch a
        storage node, and missed bytes are admitted for the next job.
        ``tenant`` identifies the requesting job for the cache's per-tenant
        capacity shares and accounting."""
        with self._mutate_lock:
            # atomic snapshot vs append/rewrite: bytes, placement, and the
            # path's dedup generation all belong to one file version —
            # and vs attach_cache/attach_tracer: `cache`/`tracer` are
            # published under this lock, so testing `cache` again
            # outside it would be double-checked locking (REPRO-R002)
            data = self._files[path]
            refs = self._blocks[path]
            cache = self.cache
            tracer = self.tracer
            gen0 = (
                cache.dedup.generation(path)
                if cache is not None else 0
            )
        out: List[bytes] = []
        storage_b = dram_b = flash_b = 0
        for off, length in extents:
            assert off + length <= len(data), (off, length, len(data))
            if cache is None:
                block_idx = off // BLOCK_BYTES
                node = self.nodes[refs[min(block_idx, len(refs) - 1)].node_ids[0]]
                with tracer.span(
                    "storage.read", tenant=tenant or "", path=path,
                    bytes=length,
                ):
                    with self._stats_lock:
                        node.read(length)
                        self.stats.record(length, node.media)
                    self._simulate_latency(node.media, length)
                storage_b += length
                out.append(data[off: off + length])
                continue
            # cut the extent at registered stripe boundaries so cache units
            # are content-addressable even when coalescing spans stripes;
            # contiguous missed segments merge back into single storage I/Os
            parts: List[bytes] = []
            pending_off = pending_len = 0

            def _flush_storage() -> None:
                nonlocal pending_off, pending_len, storage_b
                if pending_len == 0:
                    return
                block_idx = pending_off // BLOCK_BYTES
                node = self.nodes[refs[min(block_idx, len(refs) - 1)].node_ids[0]]
                # cache.fill: the storage I/O behind a merged miss run —
                # the fill cost the cache tier pays on behalf of this read
                with tracer.span(
                    "cache.fill", tenant=tenant or "", path=path,
                    bytes=pending_len,
                ):
                    with self._stats_lock:
                        node.read(pending_len)
                        self.stats.record(pending_len, node.media)
                    self._simulate_latency(node.media, pending_len)
                storage_b += pending_len
                pending_len = 0

            for seg_off, seg_len in cache.dedup.segments(path, off, length):
                key = cache.resolve(path, seg_off, seg_len)
                # single-flight get: concurrent sessions missing the same
                # stripe wait for one fill instead of re-reading storage
                hit = cache.get_or_claim(key, tenant=tenant)
                if hit is not None:
                    _flush_storage()
                    if hit.tier == "dram":
                        dram_b += seg_len
                    else:
                        flash_b += seg_len
                    if tracer.enabled:
                        tracer.instant(
                            "cache.hit", tenant=tenant or "", tier=hit.tier,
                            bytes=seg_len,
                        )
                    parts.append(hit.payload)
                    continue
                if tracer.enabled:
                    tracer.instant(
                        "cache.miss", tenant=tenant or "", bytes=seg_len,
                    )
                try:
                    blob = data[seg_off: seg_off + seg_len]
                except BaseException:
                    cache.abort(key)
                    raise
                if cache.dedup.generation(path) != gen0:
                    # a rewrite landed after our snapshot: ``key`` now
                    # describes the NEW file version while ``blob`` holds
                    # the old bytes — admitting would poison post-rewrite
                    # readers.  Serve our (consistent, pre-rewrite) bytes
                    # but leave the cache alone.
                    cache.abort(key)
                else:
                    cache.admit(key, blob, tenant=tenant)  # releases claim
                parts.append(blob)
                if pending_len == 0:
                    pending_off = seg_off
                pending_len += seg_len
            _flush_storage()
            out.append(b"".join(parts))
        return ExtentRead(
            blobs=out, storage_bytes=storage_b,
            dram_bytes=dram_b, flash_bytes=flash_b,
        )

    def read_all(self, path: str) -> bytes:
        return self.read_extents(path, [(0, len(self._files[path]))])[0]

    # -- fleet metrics (Fig. 1 / §7.1 style) --------------------------------

    def reset_stats(self) -> None:
        # a reset racing a concurrent read's stats.record would lose the
        # in-flight I/O or resurrect the pre-reset counters
        with self._stats_lock:
            self.stats = IOStats()
            for n in self.nodes:
                n.stats = IOStats()

    def power_W(self) -> float:
        return sum(n.media.power_W for n in self.nodes)

    def throughput_to_storage_gap(self, demand_MBps: float) -> float:
        """How many x more capacity we must provision to meet IOPS demand
        (the paper's ~8x observation for HDD)."""
        per_node_MBps = self.media.transfer_MBps
        nodes_for_bw = demand_MBps / per_node_MBps
        bytes_needed = self.used_bytes * REPLICATION
        nodes_for_cap = bytes_needed / (self.media.capacity_TB * 1e12)
        if nodes_for_cap == 0:
            return 0.0
        return nodes_for_bw / nodes_for_cap
