"""End-to-end driver: train a ~100M-parameter DLRM for a few hundred steps
through the full DSI pipeline, with checkpoint/restart and DPP worker
fault injection along the way.

  PYTHONPATH=src python examples/train_dlrm_e2e.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs.dlrm_paper import SMOKE
from repro.launch.train import dlrm_dpp_batches
from repro.models import build_model
from repro.models.common import param_count
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # ~100M params: 32 tables x 100k vocab x 32-dim = 102M embedding params
    cfg = dataclasses.replace(
        SMOKE,
        name="dlrm-100m",
        num_dense=64,
        num_tables=32,
        vocab_per_table=100_000,
        embed_dim=32,
        max_ids_per_feature=16,
        bottom_mlp=(128, 64, 32),
        top_mlp=(256, 128, 1),
    )
    n = param_count(build_model(cfg).param_specs())
    print(f"DLRM params: {n/1e6:.1f}M")

    ckpt_dir = tempfile.mkdtemp(prefix="dlrm_ckpt_")
    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=1e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(checkpoint_dir=ckpt_dir, checkpoint_every=50, max_steps=args.steps),
    )

    # phase 1: train halfway, then simulate a trainer crash
    batches, session = dlrm_dpp_batches(
        cfg, batch_size=256, n_partitions=4, rows_per_partition=8192, n_workers=3
    )
    trainer.cfg.max_steps = args.steps // 2
    state = trainer.fit(batches)
    session.stop()
    print(f"phase 1 done at step {state['step']}; 'crashing' and restoring...")

    # phase 2: fresh trainer restores from the checkpoint and finishes
    trainer2 = Trainer(
        cfg,
        OptimizerConfig(learning_rate=1e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(checkpoint_dir=ckpt_dir, checkpoint_every=50, max_steps=args.steps),
    )
    batches2, session2 = dlrm_dpp_batches(
        cfg, batch_size=256, n_partitions=4, rows_per_partition=8192, n_workers=3
    )
    state2 = trainer2.fit(batches2)
    session2.stop()

    losses = [m.loss for m in trainer.history] + [m.loss for m in trainer2.history]
    print(f"resumed at step {trainer2.history[0].step}, finished at {state2['step']}")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(f"stall fraction phase2: {trainer2.stall_fraction():.3f}")
    assert trainer2.history[0].step > args.steps // 4, "did not resume from checkpoint"
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
