import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_module


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jnp.zeros((64, 64), jnp.bfloat16)
    w = jnp.zeros((10, 64, 64), jnp.bfloat16)
    c = jax.jit(f).lower(x, w).compile()
    cost = analyze(c.as_text())
    assert abs(cost.flops - 2 * 64 ** 3 * 10) / (2 * 64 ** 3 * 10) < 0.01


def test_matches_xla_on_loop_free_module():
    def g(x, w1, w2):
        h = jax.nn.relu(x @ w1)
        return jnp.sum(h @ w2)
    x = jnp.zeros((128, 256), jnp.float32)
    w1 = jnp.zeros((256, 512), jnp.float32)
    w2 = jnp.zeros((512, 64), jnp.float32)
    c = jax.jit(jax.grad(g, argnums=(1, 2))).lower(x, w1, w2).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    mine = analyze(c.as_text())
    assert abs(mine.flops - ca["flops"]) / ca["flops"] < 0.02
    assert abs(mine.bytes_accessed - ca["bytes accessed"]) / ca["bytes accessed"] < 0.05


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y
    x = jnp.zeros((32, 32), jnp.float32)
    w = jnp.zeros((4, 32, 32), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    cost = analyze(c.as_text())
    expected = 2 * 32 ** 3 * 4 * 5
    assert abs(cost.flops - expected) / expected < 0.01


def test_parse_module_entry():
    c = jax.jit(lambda x: x + 1).lower(jnp.ones(4)).compile()
    comps = parse_module(c.as_text())
    assert any(comp.is_entry for comp in comps.values())
