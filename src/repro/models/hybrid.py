"""Jamba-style hybrid: blocks of (attention : Mamba = 1 : 7) with MoE FFNs.

Layers are grouped into ``block_period``-sized blocks; the model scans over
blocks (stacked params), with the block body unrolled: one attention
sublayer at ``attn_index`` and SSM mixers elsewhere, FFNs alternating
dense-MLP / MoE (MoE on odd in-block indices, i.e. every other layer).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import attention as attn
from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.common import ModelConfig, ParamSpec, stack_tree
from repro.models.transformer import DecoderLM


class HybridLM(DecoderLM):
    def __init__(self, cfg: ModelConfig):
        assert cfg.block_period > 0 and cfg.num_layers % cfg.block_period == 0
        super().__init__(cfg)
        self.n_blocks = cfg.num_layers // cfg.block_period

    def _is_attn(self, i: int) -> bool:
        return i == self.cfg.attn_index

    def _is_moe(self, i: int) -> bool:
        return bool(self.cfg.moe) and (i % max(self.cfg.moe_period, 1) == 1)

    def block_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        for i in range(cfg.block_period):
            sub: Dict[str, Any] = {
                "ln1": layers.rmsnorm_spec(cfg.d_model),
                "ln2": layers.rmsnorm_spec(cfg.d_model),
            }
            sub["mixer"] = attn.gqa_specs(cfg) if self._is_attn(i) else ssm_lib.ssm_specs(cfg)
            sub["ffn"] = (
                moe_lib.moe_specs(cfg)
                if self._is_moe(i)
                else layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.param_dtype)
            )
            specs[f"sub{i}"] = sub
        return specs

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": layers.embed_specs(cfg),
            "blocks": stack_tree(self.block_specs(), self.n_blocks),
            "ln_f": layers.rmsnorm_spec(cfg.d_model),
        }

    # -- training forward ----------------------------------------------------

    def _block_train(self, bp: Dict[str, Any], x: jax.Array, positions: jax.Array):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.block_period):
            sp = bp[f"sub{i}"]
            # sequence parallelism on the residual stream (see transformer.py)
            x = constrain(x, ("batch", "seq_sp", None))
            h = constrain(
                layers.rmsnorm(x, sp["ln1"], cfg.rms_eps), ("batch", "seq_sp", None)
            )
            if self._is_attn(i):
                q, k, v = attn.gqa_project_qkv(sp["mixer"], h, positions, cfg)
                o = attn.blocked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
                mix = jnp.einsum("bshk,hkd->bsd", o, sp["mixer"]["wo"])
            else:
                mix = ssm_lib.ssm_forward(sp["mixer"], h, cfg)
            x = constrain(x + mix, ("batch", "seq_sp", None))
            h = constrain(
                layers.rmsnorm(x, sp["ln2"], cfg.rms_eps), ("batch", "seq_sp", None)
            )
            if self._is_moe(i):
                f, a = moe_lib.moe_forward(sp["ffn"], h, cfg)
                aux = aux + a
            else:
                f = layers.mlp(sp["ffn"], h)
            x = constrain(x + f, ("batch", "seq_sp", None))
        return x, aux

    def backbone(self, params: Dict[str, Any], x: jax.Array, positions: jax.Array):
        cfg = self.cfg

        def body(carry, bp):
            h, aux = carry
            h2, a = self._block_train(bp, h, positions)
            return (h2, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        return layers.rmsnorm(x, params["ln_f"], cfg.rms_eps), aux

    # -- caches ---------------------------------------------------------------

    def abstract_cache(self, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        s_cfg = cfg.ssm
        nb = self.n_blocks
        n_ssm = cfg.block_period - 1
        din = s_cfg.d_inner(cfg.d_model)
        h = s_cfg.n_heads(cfg.d_model)
        gn = s_cfg.n_groups * s_cfg.d_state
        dt = cfg.compute_dtype
        return {
            "k": jax.ShapeDtypeStruct((nb, batch, seq, cfg.num_kv_heads, cfg.head_dim), dt),
            "v": jax.ShapeDtypeStruct((nb, batch, seq, cfg.num_kv_heads, cfg.head_dim), dt),
            "state": jax.ShapeDtypeStruct(
                (nb, n_ssm, batch, h, s_cfg.head_dim, s_cfg.d_state), jnp.float32
            ),
            "conv_x": jax.ShapeDtypeStruct((nb, n_ssm, batch, s_cfg.conv_width - 1, din), dt),
            "conv_B": jax.ShapeDtypeStruct((nb, n_ssm, batch, s_cfg.conv_width - 1, gn), dt),
            "conv_C": jax.ShapeDtypeStruct((nb, n_ssm, batch, s_cfg.conv_width - 1, gn), dt),
        }

    def cache_logical_axes(self) -> Dict[str, Tuple]:
        return {
            "k": ("stack", "batch", "kv_seq", "kv_heads", None),
            "v": ("stack", "batch", "kv_seq", "kv_heads", None),
            "state": ("stack", None, "batch", "ssm_heads", None, None),
            "conv_x": ("stack", None, "batch", None, "mlp"),
            "conv_B": ("stack", None, "batch", None, None),
            "conv_C": ("stack", None, "batch", None, None),
        }

    # -- serving --------------------------------------------------------------

    def prefill(self, params: Dict[str, Any], batch: Dict[str, jax.Array]):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = layers.embed_tokens(params["embed"], tokens, cfg)
        s_cfg = cfg.ssm

        def body(h, bp):
            caches: Dict[str, Any] = {}
            ssm_states, conv_xs, conv_bs, conv_cs = [], [], [], []
            for i in range(cfg.block_period):
                sp = bp[f"sub{i}"]
                hn = layers.rmsnorm(h, sp["ln1"], cfg.rms_eps)
                if self._is_attn(i):
                    q, k, v = attn.gqa_project_qkv(sp["mixer"], hn, positions, cfg)
                    o = attn.blocked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
                    mix = jnp.einsum("bshk,hkd->bsd", o, sp["mixer"]["wo"])
                    caches["k"] = k.astype(cfg.compute_dtype)
                    caches["v"] = v.astype(cfg.compute_dtype)
                else:
                    mix, st, cx, cb_, cc = _ssm_prefill_with_state(sp["mixer"], hn, cfg)
                    ssm_states.append(st)
                    conv_xs.append(cx)
                    conv_bs.append(cb_)
                    conv_cs.append(cc)
                h = h + mix
                hn = layers.rmsnorm(h, sp["ln2"], cfg.rms_eps)
                if self._is_moe(i):
                    f, _ = moe_lib.moe_forward(sp["ffn"], hn, cfg)
                else:
                    f = layers.mlp(sp["ffn"], hn)
                h = h + f
            caches["state"] = jnp.stack(ssm_states)
            caches["conv_x"] = jnp.stack(conv_xs)
            caches["conv_B"] = jnp.stack(conv_bs)
            caches["conv_C"] = jnp.stack(conv_cs)
            return h, caches

        x, cache = jax.lax.scan(body, x, params["blocks"])
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x[:, -1:, :], cfg)
        return logits, cache

    def decode_step(self, params: Dict[str, Any], batch: Dict[str, Any]):
        cfg = self.cfg
        token, pos, cache = batch["token"], batch["pos"], batch["cache"]
        x = layers.embed_tokens(params["embed"], token, cfg)
        positions = jnp.broadcast_to(pos, token.shape)

        def body(h, inp):
            bp, k_c, v_c, states, conv_x, conv_B, conv_C = inp
            new_states, new_cx, new_cb, new_cc = [], [], [], []
            ssm_i = 0
            for i in range(cfg.block_period):
                sp = bp[f"sub{i}"]
                hn = layers.rmsnorm(h, sp["ln1"], cfg.rms_eps)
                if self._is_attn(i):
                    q, k, v = attn.gqa_project_qkv(sp["mixer"], hn, positions, cfg)
                    k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, pos, 0, 0))
                    v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, pos, 0, 0))
                    o = attn.decode_attention(q, k_c, v_c, pos)
                    mix = jnp.einsum("bshk,hkd->bsd", o, sp["mixer"]["wo"])
                else:
                    sub_cache = {
                        "state": states[ssm_i],
                        "conv_x": conv_x[ssm_i],
                        "conv_B": conv_B[ssm_i],
                        "conv_C": conv_C[ssm_i],
                    }
                    mix, sub_cache = ssm_lib.ssm_decode_step(sp["mixer"], hn, sub_cache, cfg)
                    new_states.append(sub_cache["state"])
                    new_cx.append(sub_cache["conv_x"])
                    new_cb.append(sub_cache["conv_B"])
                    new_cc.append(sub_cache["conv_C"])
                    ssm_i += 1
                h = h + mix
                hn = layers.rmsnorm(h, sp["ln2"], cfg.rms_eps)
                if self._is_moe(i):
                    f, _ = moe_lib.moe_forward(sp["ffn"], hn, cfg)
                else:
                    f = layers.mlp(sp["ffn"], hn)
                h = h + f
            new_cache = {
                "k": k_c,
                "v": v_c,
                "state": jnp.stack(new_states),
                "conv_x": jnp.stack(new_cx),
                "conv_B": jnp.stack(new_cb),
                "conv_C": jnp.stack(new_cc),
            }
            return h, new_cache

        xs = (
            params["blocks"], cache["k"], cache["v"], cache["state"],
            cache["conv_x"], cache["conv_B"], cache["conv_C"],
        )
        x, new_cache = jax.lax.scan(body, x, xs)
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x, cfg)
        return logits, new_cache


def _ssm_prefill_with_state(params, x, cfg: ModelConfig):
    """Mamba-2 prefill that also returns the final SSM + conv states."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    din = s_cfg.d_inner(d)
    h = s_cfg.n_heads(d)
    p = s_cfg.head_dim
    g, n = s_cfg.n_groups, s_cfg.d_state
    w = s_cfg.conv_width

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xi_raw = jnp.einsum("bsd,de->bse", x, params["wx"])
    Bv_raw = jnp.einsum("bsd,de->bse", x, params["wB"])
    Cv_raw = jnp.einsum("bsd,de->bse", x, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"]).astype(jnp.float32)

    conv_x = xi_raw[:, -(w - 1):, :].astype(cfg.compute_dtype)
    conv_B = Bv_raw[:, -(w - 1):, :].astype(cfg.compute_dtype)
    conv_C = Cv_raw[:, -(w - 1):, :].astype(cfg.compute_dtype)

    xi = jax.nn.silu(ssm_lib._causal_conv(xi_raw, params["conv_x"]).astype(jnp.float32)).astype(x.dtype)
    Bv = jax.nn.silu(ssm_lib._causal_conv(Bv_raw, params["conv_B"]).astype(jnp.float32)).astype(x.dtype)
    Cv = jax.nn.silu(ssm_lib._causal_conv(Cv_raw, params["conv_C"]).astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    b, s = x.shape[:2]
    y, state = ssm_lib.ssd_chunked(
        xi.reshape(b, s, h, p), dt, A,
        Bv.reshape(b, s, g, n), Cv.reshape(b, s, g, n), chunk=s_cfg.chunk,
    )
    y = y + xi.reshape(b, s, h, p) * params["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, din)
    y = layers.rmsnorm(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"], cfg.rms_eps
    )
    out = jnp.einsum("bse,ed->bsd", y, params["out"])
    return out, state, conv_x, conv_B, conv_C
