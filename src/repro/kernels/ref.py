"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _mix64(x: jax.Array) -> jax.Array:
    """splitmix64-style mixer on uint32 pairs (TPU-friendly 32-bit lanes).

    We operate on uint32 (TPU vector lanes are 32-bit); the hash is a pair of
    multiply-xor-shift rounds — identical math in kernel and oracle.
    """
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x = x * jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x = x * jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def sigrid_hash(ids: jax.Array, salt: int, max_value: int) -> jax.Array:
    """ids: int32 (any shape) -> hashed ids in [0, max_value), int32."""
    h = _mix64(ids.astype(jnp.uint32) ^ jnp.uint32(salt))
    return (h % jnp.uint32(max_value)).astype(jnp.int32)


def bucketize(values: jax.Array, borders: jax.Array) -> jax.Array:
    """values: f32 (any shape); borders: (nb,) sorted -> bucket idx int32.

    Counts borders strictly below the value — ``np.searchsorted(borders,
    v)`` (side='left'), the semantics of ``repro.core.transforms.bucketize``.
    """
    return jnp.sum(
        values[..., None] > borders, axis=-1, dtype=jnp.int32
    )


# fused multi-feature transform op codes (mirrors kernels.fused_transform)
OP_IDENTITY = 0
OP_SIGRID_HASH = 1
OP_POSITIVE_MODULUS = 2
OP_CLAMP = 3
OP_BUCKETIZE = 4
OP_CLAMP_F = 5
OP_BUCKETIZE_F = 6


def fused_transform(
    ids: jax.Array,        # (rows, features) int32 packed feature matrix
    op_codes: jax.Array,   # (features,) int32
    param0: jax.Array,     # (features,) int32  (salt / modulus / lo-bits)
    param1: jax.Array,     # (features,) int32  (max_value / hi-bits / scale)
    borders=None,          # (features, nb) f32 +inf-padded (BUCKETIZE_F)
) -> jax.Array:
    """Apply a per-feature op across a packed (rows, features) tile — the
    paper's 'combine 1000 features into one kernel' insight (§7.2).
    Float-typed ops (CLAMP_F / BUCKETIZE_F) treat the lane as float32 bits."""
    rows, feats = ids.shape
    if borders is None:
        borders = jnp.full((feats, 1), jnp.inf, jnp.float32)
    h = _mix64(ids.astype(jnp.uint32) ^ param0[None, :].astype(jnp.uint32))
    out_hash = (h % jnp.maximum(param1[None, :].astype(jnp.uint32), 1)).astype(jnp.int32)
    m = jnp.maximum(param1[None, :], 1)
    # single floored mod: already in [0, m), and immune to the int32
    # overflow a mod(mod+m, m) chain hits for m near 2^31
    out_mod = jnp.mod(ids, m)
    out_clamp = jnp.clip(ids, param0[None, :], param1[None, :])
    # bucketize against a linear grid: idx = clip(floor((v - lo)/scale), 0, n)
    scale = jnp.maximum(param1[None, :], 1)
    out_bucket = jnp.clip((ids - param0[None, :]) // scale, 0, 255)
    f = jax.lax.bitcast_convert_type(ids, jnp.float32)
    lo = jax.lax.bitcast_convert_type(param0, jnp.float32)[None, :]
    hi = jax.lax.bitcast_convert_type(param1, jnp.float32)[None, :]
    out_clamp_f = jax.lax.bitcast_convert_type(jnp.clip(f, lo, hi), jnp.int32)
    out_bucket_f = jnp.sum(
        f[:, :, None] > borders[None, :, :], axis=-1, dtype=jnp.int32
    )
    code = op_codes[None, :]
    out = jnp.where(code == OP_SIGRID_HASH, out_hash, ids)
    out = jnp.where(code == OP_POSITIVE_MODULUS, out_mod, out)
    out = jnp.where(code == OP_CLAMP, out_clamp, out)
    out = jnp.where(code == OP_BUCKETIZE, out_bucket, out)
    out = jnp.where(code == OP_CLAMP_F, out_clamp_f, out)
    out = jnp.where(code == OP_BUCKETIZE_F, out_bucket_f, out)
    return out.astype(jnp.int32)


def fused_transform_static(
    ids: jax.Array,
    op_codes,              # STATIC tuple[int, ...] of per-feature op codes
    param0: jax.Array,
    param1: jax.Array,
    borders=None,
    features_major: bool = False,     # STATIC: ids is (features, rows)
) -> jax.Array:
    """``fused_transform`` with compile-time op codes: only the branches
    that actually occur are built, so an all-SigridHash wave costs one
    hash pass instead of every candidate op tile-wide.  Identical bits to
    ``fused_transform`` — the fast fused path when the wave dispatcher
    compiles for CPU/GPU instead of launching the Pallas TPU kernel.

    ``features_major=True`` computes in the engine's packing layout
    ((features, rows), one contiguous row per feature) with no transpose
    on either side of the call."""
    ax = (slice(None), None) if features_major else (None, slice(None))
    nf = ids.shape[0] if features_major else ids.shape[1]
    present = set(int(c) for c in op_codes)
    code = jnp.asarray(op_codes, jnp.int32)[ax]
    out = ids
    if OP_SIGRID_HASH in present:
        h = _mix64(ids.astype(jnp.uint32) ^ param0[ax].astype(jnp.uint32))
        hashed = (
            h % jnp.maximum(param1[ax].astype(jnp.uint32), 1)
        ).astype(jnp.int32)
        out = jnp.where(code == OP_SIGRID_HASH, hashed, out)
    if OP_POSITIVE_MODULUS in present:
        out = jnp.where(
            code == OP_POSITIVE_MODULUS,
            jnp.mod(ids, jnp.maximum(param1[ax], 1)), out,
        )
    if OP_CLAMP in present:
        out = jnp.where(
            code == OP_CLAMP, jnp.clip(ids, param0[ax], param1[ax]), out,
        )
    if OP_BUCKETIZE in present:
        scale = jnp.maximum(param1[ax], 1)
        out = jnp.where(
            code == OP_BUCKETIZE,
            jnp.clip((ids - param0[ax]) // scale, 0, 255), out,
        )
    if OP_CLAMP_F in present or OP_BUCKETIZE_F in present:
        f = jax.lax.bitcast_convert_type(ids, jnp.float32)
        if OP_CLAMP_F in present:
            lo = jax.lax.bitcast_convert_type(param0, jnp.float32)[ax]
            hi = jax.lax.bitcast_convert_type(param1, jnp.float32)[ax]
            out = jnp.where(
                code == OP_CLAMP_F,
                jax.lax.bitcast_convert_type(jnp.clip(f, lo, hi), jnp.int32),
                out,
            )
        if OP_BUCKETIZE_F in present:
            if borders is None:
                borders = jnp.full((nf, 1), jnp.inf, jnp.float32)
            cmp = (
                f[:, :, None] > borders[:, None, :] if features_major
                else f[:, :, None] > borders[None, :, :]
            )
            out = jnp.where(
                code == OP_BUCKETIZE_F,
                jnp.sum(cmp, axis=-1, dtype=jnp.int32), out,
            )
    return out.astype(jnp.int32)


# batched stripe-decode oracles (mirror kernels.decode — §6.3 extract)
XOR_KEY32 = 0x5A5A5A5A        # dwrf._XOR_KEY replicated into each byte
NAN_BITS = 0x7FC00000         # float32 quiet-NaN bits (np.full(nan) fill)


def xor_decrypt(words: jax.Array) -> jax.Array:
    """(n, 128) int32 stream words -> XOR-decrypted words (byte-wise XOR
    is position-local, so the little-endian word view is exact)."""
    return words ^ jnp.int32(XOR_KEY32)


def dense_unpack(bitmap_words: jax.Array, values: jax.Array) -> jax.Array:
    """Batched presence-bitmap unpack + dense scatter.

    bitmap_words: (F, W) int32 — ``np.packbits`` bytes as LE words;
    values: (F, C) int32 — present float32 values as bit patterns.
    Returns (F, W*32) int32 f32 bits, NaN bits where absent.
    """
    feats, w = bitmap_words.shape
    lane = jnp.arange(32, dtype=jnp.int32)[None, None, :]
    # packbits is MSB-first per byte; LE words put row 32w+k at bit
    # 8*(k//8) + 7 - (k%8)
    shift = (lane & ~7) + 7 - (lane & 7)
    bits = jax.lax.shift_right_logical(bitmap_words[:, :, None], shift) & 1
    bits = bits.reshape(feats, w * 32)
    rank = jnp.cumsum(bits, axis=1) - 1
    idx = jnp.clip(rank, 0, values.shape[1] - 1)
    gathered = jnp.take_along_axis(values, idx, axis=1)
    return jnp.where(bits == 1, gathered, jnp.int32(NAN_BITS))


def ragged_gather(src: jax.Array, idx: jax.Array, shift: jax.Array) -> jax.Array:
    """Byte-unaligned word gather: out = src[idx] >> shift | src[idx+1] <<
    (32-shift).  src: (S, 128) i32; idx/shift: (M, 128) i32."""
    flat = src.reshape(-1)
    lo = jax.lax.shift_right_logical(jnp.take(flat, idx, axis=0), shift)
    hi = jnp.take(flat, idx + 1, axis=0)
    hi = jnp.where(shift == 0, 0, jax.lax.shift_left(hi, (32 - shift) & 31))
    return lo | hi


def embedding_bag(
    table: jax.Array,       # (V, E) f32
    ids: jax.Array,         # (B, L) int32
    mask: jax.Array,        # (B, L) f32
    mode: str = "mean",     # "mean" | "sum"
) -> jax.Array:
    """Pooled embedding bag -> (B, E); mean divides by max(sum(mask), 1)."""
    if mode not in ("mean", "sum"):
        raise ValueError(f"mode must be 'mean' or 'sum', got {mode!r}")
    emb = jnp.take(table, ids, axis=0)                  # (B, L, E)
    s = jnp.sum(emb * mask[..., None], axis=1)
    if mode == "sum":
        return s
    denom = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    return s / denom[:, None]


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """q,k,v: (B, H, S, D) -> (B, H, S, D); fp32 softmax."""
    d = q.shape[-1]
    sc = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / jnp.sqrt(d)
    if causal:
        s, t = q.shape[2], k.shape[2]
        mask = jnp.arange(s)[:, None] >= jnp.arange(t)[None, :]
        sc = jnp.where(mask, sc, -2.0e38)
    p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def ssd_chunk_forward(x, dt, a, b_, c_):
    """SSD recurrence oracle, sequential over time.

    x: (BH, S, P); dt: (BH, S); a: (BH,); b_, c_: (BH, S, N)."""
    bh, s, p = x.shape
    n = b_.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                      # (BH,P),(BH,),(BH,N),(BH,N)
        da = jnp.exp(dtt * a)                      # (BH,)
        state = state * da[:, None, None] + jnp.einsum(
            "bn,bp,b->bnp", bt, xt, dtt
        )
        y = jnp.einsum("bnp,bn->bp", state, ct)
        return state, y

    state0 = jnp.zeros((bh, n, p), jnp.float32)
    xs = (
        x.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
        b_.swapaxes(0, 1).astype(jnp.float32),
        c_.swapaxes(0, 1).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)
