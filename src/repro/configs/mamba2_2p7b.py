"""mamba2-2.7b — SSD (state-space duality) LM [arXiv:2405.21060]."""
import dataclasses
from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    sharding_profile="fsdp",
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="mamba2-smoke",
    num_layers=2,
    d_model=128,
    vocab_size=512,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4, chunk=32),
    remat=False,
)
