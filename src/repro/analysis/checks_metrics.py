"""Metrics-contract rules (REPRO-M001/M002).

The benchmarks are the repo's paper-facing numbers; they read
``WorkerMetrics``/``TierStats``/``CacheStats``/... fields by attribute.
A renamed or deleted field turns a Table-9-style benchmark into an
``AttributeError`` at best and a silently-wrong derived metric at worst.

  * **M001** — every metric attribute a benchmark reads must exist on one
    of the metric dataclasses (fields, ``@property``s, and methods all
    count).  Receivers are recognized two ways: chained access through a
    ``.metrics`` / ``.stats`` attribute (``sess.prefetcher.metrics.fills``),
    and locals assigned from a metrics getter
    (``m = sess.worker_metrics()``; ``stats = engine.stats``) — tracking
    is dropped on reassignment, so ``m = table.partitions[p]`` is never
    misread as a metrics object.
  * **M002** — metric counters are monotonic: ``x.hits -= 1`` (or
    ``x.hits = x.hits - k``) anywhere in ``src/repro`` is a finding.
    Capacity gauges legitimately shrink and are exempt: ``bytes_stored``
    (eviction) and ``buffered_batches`` (drain).

The metric vocabulary is parsed from the source of the metric classes
listed in ``METRIC_CLASSES`` — if one goes missing the checker reports
that as drift instead of silently checking nothing.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (
    CheckContext,
    Finding,
    attr_chain,
    checker,
    enclosing_symbol,
    rule,
)

M001 = rule("REPRO-M001",
            "benchmark reads a metric attribute that no metric class "
            "defines")
M002 = rule("REPRO-M002",
            "metric counter decremented (counters are monotonic; only "
            "gauges may shrink)")

# module -> metric classes it must define
METRIC_CLASSES: Dict[str, Tuple[str, ...]] = {
    "src/repro/core/dpp/worker.py": ("WorkerMetrics",),
    "src/repro/core/dpp/client.py": ("ClientMetrics",),
    "src/repro/core/dpp/prefetch.py": ("PrefetchMetrics",),
    "src/repro/core/dpp/tensor_cache.py": ("CacheStats",),
    "src/repro/core/cache/stripe_cache.py": ("TierStats", "TenantStats"),
    "src/repro/core/cache/dedup.py": ("DedupStats",),
    "src/repro/core/tectonic.py": ("IOStats",),
    "src/repro/core/engine.py": ("EngineStats",),
    "src/repro/train/trainer.py": ("StepMetrics",),
}

# fields that measure *current occupancy*, not cumulative work
GAUGE_FIELDS = {"bytes_stored", "buffered_batches"}

_GETTER_CALLS = {"worker_metrics", "fleet_metrics"}
_METRIC_ATTRS = {"metrics", "stats"}


def _class_vocab(cls: ast.ClassDef) -> Set[str]:
    names: Set[str] = set()
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            names.add(node.target.id)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
    return names


def _load_vocab(ctx: CheckContext) -> Tuple[Set[str], Set[str], List[Finding]]:
    """(full vocabulary, counter fields, drift findings)."""
    vocab: Set[str] = set(_METRIC_ATTRS)   # x.metrics.stats... chains
    counters: Set[str] = set()
    drift: List[Finding] = []
    for rel, classes in METRIC_CLASSES.items():
        mod = ctx.load(rel)
        found = {
            n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, ast.ClassDef)
        } if mod is not None else {}
        for cname in classes:
            cls = found.get(cname)
            if cls is None:
                drift.append(Finding(
                    M001, rel, 1,
                    f"metric class {cname} not found — update "
                    "repro/analysis/checks_metrics.py METRIC_CLASSES",
                ))
                continue
            vocab |= _class_vocab(cls)
            for node in cls.body:
                if isinstance(node, ast.AnnAssign) \
                        and isinstance(node.target, ast.Name) \
                        and node.target.id not in GAUGE_FIELDS:
                    counters.add(node.target.id)
    return vocab, counters, drift


class _BenchScan(ast.NodeVisitor):
    """Per-function tracking of metrics-typed locals + attribute reads."""

    def __init__(self, vocab: Set[str]):
        self.vocab = vocab
        self.tracked: Set[str] = set()
        self.stack: List[ast.AST] = []
        self.bad: List[Tuple[int, str, str]] = []   # (line, attr, symbol)

    def _push(self, node):
        self.stack.append(node)
        self.generic_visit(node)
        self.stack.pop()

    visit_ClassDef = visit_FunctionDef = visit_AsyncFunctionDef = _push

    def _is_metrics_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            return node.func.attr in _GETTER_CALLS
        if isinstance(node, ast.Attribute):
            return node.attr in _METRIC_ATTRS
        if isinstance(node, ast.Name):
            return node.id in self.tracked
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        metric = self._is_metrics_expr(node.value)
        for t in node.targets:
            if isinstance(t, ast.Name):
                if metric:
                    self.tracked.add(t.id)
                else:
                    self.tracked.discard(t.id)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        recv = node.value
        is_metric_recv = (
            (isinstance(recv, ast.Name) and recv.id in self.tracked)
            or (isinstance(recv, ast.Attribute) and recv.attr in _METRIC_ATTRS)
        )
        if is_metric_recv and node.attr not in self.vocab:
            self.bad.append(
                (node.lineno, node.attr, enclosing_symbol(self.stack))
            )
        self.generic_visit(node)


@checker("metrics-contract")
def check_metrics(ctx: CheckContext):
    vocab, counters, findings = _load_vocab(ctx)
    for mod in ctx.glob_modules("benchmarks/*.py"):
        scan = _BenchScan(vocab)
        scan.visit(mod.tree)
        for line, attr, sym in scan.bad:
            findings.append(Finding(
                M001, mod.rel, line,
                f"reads .{attr} on a metrics object but no metric class "
                "defines it — renamed field or stale benchmark",
                sym,
            ))
    for mod in ctx.src_modules():
        for node in ast.walk(mod.tree):
            target = None
            if isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Sub):
                target = node.target
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.value, ast.BinOp) \
                    and isinstance(node.value.op, ast.Sub):
                t, lhs = node.targets[0], node.value.left
                if isinstance(t, ast.Attribute) and isinstance(lhs, ast.Attribute) \
                        and t.attr == lhs.attr \
                        and attr_chain(t) == attr_chain(lhs):
                    target = t
            if isinstance(target, ast.Attribute) and target.attr in counters:
                findings.append(Finding(
                    M002, mod.rel, node.lineno,
                    f"decrements counter .{target.attr} — metric counters "
                    "are monotonic (use a gauge field if occupancy is "
                    "intended)",
                ))
    return findings
