import os

# Smoke tests and benches must see the single real CPU device (the dry-run
# sets its own XLA_FLAGS in-process; never globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# lock construction sites the lock-order sanitizer should track: repo code
# only — stdlib Condition/Queue internals stay real locks (harmless for
# cycle detection but noisy, and patching them buys nothing)
_REPRO_LOCK_FILES = (
    "stripe_cache.py", "tectonic.py", "master.py", "worker.py",
    "service.py", "client.py", "prefetch.py", "tensor_cache.py",
    "dedup.py", "warehouse.py", "autoscale.py", "engine.py", "trainer.py",
    "embedding_cache.py",
)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "lockdep: run the test under the lock-order sanitizer "
        "(module-wide via `pytestmark = pytest.mark.lockdep`)")
    config.addinivalue_line(
        "markers",
        "raced: run the test under the lockset race detector")


@pytest.fixture
def lockdep():
    """Opt-in lock-order sanitizer: every Lock/RLock a repro module builds
    during the test is tracked; teardown fails the test on any lock-order
    cycle (potential deadlock), with ordered acquisition stacks."""
    from repro.analysis import lockdep as ld

    with ld.patched(
        name_filter=lambda s: s.startswith(_REPRO_LOCK_FILES)
    ) as graph:
        yield graph
    graph.assert_no_cycles()


@pytest.fixture(autouse=True)
def _lockdep_marked(request):
    """Applies lockdep to every test carrying the `lockdep` marker (the
    whole of test_dpp.py / test_cache.py via module-level pytestmark)
    without double-patching tests that request the fixture explicitly."""
    if (request.node.get_closest_marker("lockdep") is None
            or "lockdep" in request.fixturenames):
        yield
        return
    from repro.analysis import lockdep as ld

    with ld.patched(
        name_filter=lambda s: s.startswith(_REPRO_LOCK_FILES)
    ) as graph:
        yield
    graph.assert_no_cycles()


@pytest.fixture
def raced():
    """Opt-in lockset race detector (sibling of `lockdep`): attribute
    accesses on the core threaded classes are tracked against the locks
    held at each access; teardown fails the test on any attribute shared
    across threads whose lockset intersection is empty."""
    from repro.analysis import lockdep as ld
    from repro.analysis import racedep as rd

    with ld.patched(
        name_filter=lambda s: s.startswith(_REPRO_LOCK_FILES)
    ) as graph:
        with rd.instrument(graph) as det:
            yield det
    det.assert_no_races()
    graph.assert_no_cycles()
