"""Coordinated-training simulator (§4): the collaborative release process.

Models hundreds of engineers iterating on a model via exploratory jobs,
periodic combo windows, and release candidates — producing the §4
characterization artifacts: job duration/status skew (Fig. 4), fleet
utilization peaks at combo windows (Fig. 5), per-model regional demand
(Fig. 6), and feature-lifecycle counts (Table 2 via ``TableSchema.evolve``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Job:
    kind: str                  # exploratory | combo | release_candidate
    model: str
    region: str
    start_day: float
    duration_days: float
    compute_units: float       # GPU-days/day while running
    status: str                # completed | killed | failed


@dataclasses.dataclass(frozen=True)
class ReleaseProcessConfig:
    n_models: int = 10
    n_regions: int = 5
    days: int = 365
    release_period_days: int = 30
    combo_window_days: int = 7
    exploratory_per_day: float = 12.0
    combo_jobs_per_release: int = 82           # Fig. 4's combo-job count
    rc_jobs_per_release: int = 4
    kill_rate: float = 0.35                    # lackluster jobs killed early
    fail_rate: float = 0.08
    seed: int = 0


def simulate(cfg: ReleaseProcessConfig) -> List[Job]:
    rng = np.random.default_rng(cfg.seed)
    models = [f"M{chr(ord('A') + i)}" for i in range(cfg.n_models)]
    regions = [f"R{i + 1}" for i in range(cfg.n_regions)]
    # each model prefers 1-2 regions (datasets co-located with trainers, §4.2)
    model_regions = {
        m: rng.choice(regions, size=rng.integers(1, 3), replace=False).tolist()
        for m in models
    }
    model_scale = {m: float(rng.pareto(1.1) + 0.3) for m in models}
    jobs: List[Job] = []

    def status():
        u = rng.random()
        if u < cfg.fail_rate:
            return "failed"
        if u < cfg.fail_rate + cfg.kill_rate:
            return "killed"
        return "completed"

    for m in models:
        scale = model_scale[m]
        for day in range(cfg.days):
            # exploratory: small, continuous
            n = rng.poisson(cfg.exploratory_per_day * scale / 3)
            for _ in range(n):
                st = status()
                full = float(rng.lognormal(0.2, 0.9))
                jobs.append(Job(
                    "exploratory", m, str(rng.choice(model_regions[m])),
                    day + rng.random(),
                    full * (rng.random() * 0.6 if st != "completed" else 1.0),
                    compute_units=0.2 * scale, status=st,
                ))
            # combo windows: many large concurrent jobs, temporally skewed
            phase = day % cfg.release_period_days
            if phase < cfg.combo_window_days:
                lam = cfg.combo_jobs_per_release * scale / cfg.combo_window_days / 3
                for _ in range(rng.poisson(lam)):
                    st = status()
                    full = float(rng.lognormal(1.6, 0.7))     # up to ~10+ days
                    jobs.append(Job(
                        "combo", m, str(rng.choice(model_regions[m])),
                        day + rng.random(),
                        full * (rng.random() * 0.5 if st != "completed" else 1.0),
                        compute_units=2.0 * scale, status=st,
                    ))
            # release candidates: few, large, on fresh data
            if phase == cfg.combo_window_days and rng.random() < 0.7:
                for _ in range(cfg.rc_jobs_per_release):
                    jobs.append(Job(
                        "release_candidate", m, str(rng.choice(model_regions[m])),
                        day + rng.random(), float(rng.lognormal(1.8, 0.4)),
                        compute_units=4.0 * scale, status="completed",
                    ))
    return jobs


def daily_utilization(jobs: List[Job], days: int) -> np.ndarray:
    """Fig. 5: total compute in flight per day."""
    util = np.zeros(days)
    for j in jobs:
        a = int(j.start_day)
        b = min(days, int(np.ceil(j.start_day + j.duration_days)))
        util[a:b] += j.compute_units
    return util


def regional_demand(jobs: List[Job]) -> Dict[str, Dict[str, float]]:
    """Fig. 6: per-model compute by region."""
    out: Dict[str, Dict[str, float]] = {}
    for j in jobs:
        out.setdefault(j.model, {})
        out[j.model][j.region] = out[j.model].get(j.region, 0.0) + (
            j.compute_units * j.duration_days
        )
    return out


def combo_duration_skew(jobs: List[Job]) -> Dict[str, float]:
    """Fig. 4: skewed durations + many killed/failed combo jobs."""
    durs = np.array([j.duration_days for j in jobs if j.kind == "combo"])
    statuses = [j.status for j in jobs if j.kind == "combo"]
    n = max(len(statuses), 1)
    return {
        "n_jobs": float(len(durs)),
        "p50_days": float(np.percentile(durs, 50)) if len(durs) else 0.0,
        "p95_days": float(np.percentile(durs, 95)) if len(durs) else 0.0,
        "max_days": float(durs.max()) if len(durs) else 0.0,
        "killed_frac": statuses.count("killed") / n,
        "failed_frac": statuses.count("failed") / n,
    }


def utilization_peak_to_mean(util: np.ndarray) -> float:
    return float(util.max() / max(util.mean(), 1e-9))
