"""Training runtime: DPP-fed, fault-tolerant, elastic.

The loop every trainer runs:
  batch = dpp_client.get_batch()   (data-stall accounted, Table 7 style)
  state = train_step(state, batch) (jitted, sharded)
  periodic checkpoint (atomic, resumable)

Fault tolerance: resume from the newest complete checkpoint (trainer
crash), DPP master checkpoint/restore + stateless worker restart (data
plane), and ``remesh`` for elastic scaling — re-lower the step on a new
device count and re-shard the state (parameters are resharded by device_put
under the new mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.context import sharding_context
from repro.distributed.sharding import TRAIN_RULES
from repro.models import build_model
from repro.models.common import partition_specs
from repro.obs import NULL_TRACER, gauge
from repro.optim import OptimizerConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainerConfig:
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    max_steps: int = 200
    batch_timeout_s: float = 30.0


@dataclasses.dataclass
class StepMetrics:
    """Per-step point readings — gauges, not counters: each row is one
    step's level, never accumulated across steps by ``merge_metrics``."""

    step: int = gauge(merge="last")
    loss: float = gauge(0.0, merge="last")
    grad_norm: float = gauge(0.0, merge="last")
    step_time_s: float = gauge(0.0, merge="last")
    stall_s: float = gauge(0.0, merge="last")


class Trainer:
    def __init__(
        self,
        model_cfg: Any,
        opt_cfg: Optional[OptimizerConfig] = None,
        trainer_cfg: Optional[TrainerConfig] = None,
        mesh: Optional[Any] = None,
        rules=TRAIN_RULES,
        tracer=NULL_TRACER,
    ):
        self.tracer = tracer
        self.model_cfg = model_cfg
        self.model = build_model(model_cfg)
        self.opt_cfg = opt_cfg or OptimizerConfig()
        self.cfg = trainer_cfg or TrainerConfig()
        self.mesh = mesh
        self.rules = rules
        self.ckpt = (
            CheckpointManager(self.cfg.checkpoint_dir)
            if self.cfg.checkpoint_dir
            else None
        )
        self._train_step = self._build_step()
        self.history: list[StepMetrics] = []

    # -- step ------------------------------------------------------------

    def _build_step(self) -> Callable:
        model, opt_cfg, mesh, rules = self.model, self.opt_cfg, self.mesh, self.rules

        def train_step(params, opt_state, batch):
            def run():
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                new_p, new_o, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
                return new_p, new_o, loss, gnorm

            if mesh is not None:
                with sharding_context(mesh, rules):
                    return run()
            return run()

        return jax.jit(train_step, donate_argnums=(0, 1))

    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        params = self.model.init(jax.random.PRNGKey(seed))
        if self.mesh is not None:
            specs = partition_specs(self.model.param_specs(), self.rules, self.mesh)
            from repro.distributed.sharding import shard_tree

            params = shard_tree(params, specs, self.mesh)
        return {"params": params, "opt": adamw_init(params, self.opt_cfg), "step": 0}

    # -- fault tolerance ---------------------------------------------------

    def maybe_restore(self, state: Dict[str, Any]) -> Dict[str, Any]:
        if self.ckpt and self.ckpt.latest_step() is not None:
            step, restored = self.ckpt.restore(
                {"params": state["params"], "opt": state["opt"]}
            )
            return {"params": restored["params"], "opt": restored["opt"], "step": step}
        return state

    def remesh(self, new_mesh) -> None:
        """Elastic scaling: rebuild the jitted step for a new device mesh.
        Existing state is resharded lazily on the next device_put."""
        self.mesh = new_mesh
        self._train_step = self._build_step()

    # -- loop -----------------------------------------------------------------

    def fit(
        self,
        batches: Iterable[Dict[str, np.ndarray]],
        state: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        state = state or self.init_state()
        state = self.maybe_restore(state)
        params, opt, step = state["params"], state["opt"], state["step"]

        it = iter(batches)
        while step < self.cfg.max_steps:
            t0 = time.perf_counter()
            try:
                batch = next(it)
            except StopIteration:
                break
            if batch is None:
                continue
            t1 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, loss, gnorm = self._train_step(params, opt, batch)
            step += 1
            t2 = time.perf_counter()
            if self.tracer.enabled:
                if t1 > t0:
                    # batch-fetch wait: trainer-side stall (Table 7)
                    self.tracer.record("client.stall", t0, t1, step=step)
                self.tracer.record("train.step", t1, t2, step=step)
            m = StepMetrics(
                step=step, loss=float(loss), grad_norm=float(gnorm),
                step_time_s=t2 - t1, stall_s=t1 - t0,
            )
            self.history.append(m)
            if self.ckpt and step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, {"params": params, "opt": opt})
        if self.ckpt:
            self.ckpt.save(step, {"params": params, "opt": opt})
        return {"params": params, "opt": opt, "step": step}

    # -- reporting ----------------------------------------------------------------

    def stall_fraction(self) -> float:
        tot = sum(m.step_time_s + m.stall_s for m in self.history)
        stall = sum(m.stall_s for m in self.history)
        return stall / tot if tot else 0.0
