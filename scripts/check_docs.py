#!/usr/bin/env python
"""Doc-drift gate: every repo path and `python -m` command the docs
mention must actually exist.

Scans README.md and docs/*.md for

  * `src/repro/...`, `benchmarks/...`, `tests/...`, `examples/...`,
    `scripts/...`, `docs/...` path references (with or without backticks;
    trailing `:line`, wildcards, and `...` ellipses are tolerated), and
  * `python -m <module>` / `python <script.py>` invocations,

then verifies each path exists and each module resolves under
`PYTHONPATH=src` — so a rename or deletion can never leave the
documentation silently pointing at nothing.

  PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import importlib.util
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# resolve modules the way the documented commands run them: from the repo
# root with PYTHONPATH=src
for p in (str(REPO), str(REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

PATH_RE = re.compile(
    r"(?<![\w/.-])((?:src/repro|benchmarks|tests|examples|scripts|docs)"
    r"(?:/[A-Za-z0-9_.\-*]+)*/?)"
)
MODULE_RE = re.compile(r"python\s+-m\s+([A-Za-z0-9_.]+)")
SCRIPT_RE = re.compile(r"python\s+((?:[A-Za-z0-9_\-]+/)+[A-Za-z0-9_\-]+\.py)")


def _doc_files() -> list:
    return [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))


def _check_path(ref: str) -> bool:
    # tolerate wildcard ("bench_*.py") and ellipsis ("core/...") mentions:
    # they name a family, not a file — require at least one match
    ref = ref.rstrip("/").split(":", 1)[0]
    if ref.endswith("..."):
        ref = ref[: -len("...")].rstrip("/")
    if "*" in ref:
        parent = REPO / ref.rsplit("/", 1)[0]
        return parent.is_dir() and any(parent.glob(ref.rsplit("/", 1)[1]))
    return (REPO / ref).exists()


def _check_module(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ModuleNotFoundError):
        return False


def main() -> int:
    failures = []
    checked = 0
    for doc in _doc_files():
        text = doc.read_text()
        rel = doc.relative_to(REPO)
        for m in PATH_RE.finditer(text):
            checked += 1
            if not _check_path(m.group(1)):
                failures.append(f"{rel}: missing path  {m.group(1)}")
        for m in MODULE_RE.finditer(text):
            checked += 1
            if not _check_module(m.group(1)):
                failures.append(f"{rel}: missing module python -m {m.group(1)}")
        for m in SCRIPT_RE.finditer(text):
            checked += 1
            if not (REPO / m.group(1)).is_file():
                failures.append(f"{rel}: missing script {m.group(1)}")
    if failures:
        print(f"doc drift: {len(failures)} stale reference(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"doc drift: ok ({checked} references across "
          f"{len(_doc_files())} docs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
