"""Model configuration + parameter-spec machinery.

Parameters are declared as ``ParamSpec`` trees (shape, dtype, logical axes,
initializer).  From one spec tree we derive:
  * real initialized params (for smoke tests / examples),
  * ``jax.ShapeDtypeStruct`` stand-ins (for the multi-pod dry-run),
  * ``PartitionSpec`` trees (via the logical-axis rule tables).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import AxisRules, logical_to_spec


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden
    num_shared_experts: int = 0
    shared_d_ff: int = 0            # hidden of the fused shared-expert MLP
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | moe | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 500000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # hybrid (Jamba): layers are grouped in blocks of ``block_period`` with
    # one attention layer at index ``attn_index`` and SSM elsewhere; FFN
    # alternates dense / MoE with MoE on odd in-block indices.
    block_period: int = 0
    attn_index: int = 0
    moe_period: int = 0             # every Nth ffn is MoE (hybrid); 0 = all
    # encoder-decoder
    encoder_layers: int = 0         # >0 selects the enc-dec model family
    # modality frontends (stubs; see DESIGN.md)
    frontend: Optional[str] = None  # None | "vision" | "audio"
    num_patches: int = 0            # vision tokens prepended per sample
    # numerics
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    # attention lowering
    attn_chunk: int = 1024          # online-softmax q-block size (XLA path)
    attn_k_chunk: int = 4096        # kv-block size: larger kv blocks cut the
                                    # (m,l,acc) carry re-materialization traffic
    remat: bool = True
    logit_chunk: int = 1024         # chunked cross-entropy block
    sharding_profile: str = "tp"    # "tp" (Megatron-style) | "fsdp" (H1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def active_params(self) -> int:
        """Approximate active (per-token) parameter count."""
        full = param_count(self)
        if self.moe is None:
            return full
        moe_layers = self._num_moe_layers()
        per_expert = 3 * self.d_model * self.moe.d_ff
        inactive = moe_layers * per_expert * (self.moe.num_experts - self.moe.top_k)
        return full - inactive

    def _num_moe_layers(self) -> int:
        if self.moe is None:
            return 0
        if self.family == "hybrid" and self.moe_period:
            return self.num_layers // self.moe_period
        return self.num_layers


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"            # normal | zeros | ones | scaled | conv
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "scaled":
        fan_in = spec.shape[0] if spec.shape else 1
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)
    # default: normal(0, 0.02 * scale)
    return (0.02 * spec.scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(
        spec.dtype
    )


def is_param_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree: Any, key: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_param_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    )


def abstract_params(spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=is_param_spec
    )


def partition_specs(spec_tree: Any, rules: AxisRules, mesh) -> Any:
    return jax.tree.map(
        lambda s: logical_to_spec(s.logical, rules, mesh, s.shape),
        spec_tree,
        is_leaf=is_param_spec,
    )


def param_count(cfg_or_tree: Any) -> int:
    """Total parameter count from a ModelConfig (via its spec tree) or tree."""
    tree = cfg_or_tree
    if isinstance(cfg_or_tree, ModelConfig):
        from repro.models import build_model  # lazy import to avoid a cycle

        tree = build_model(cfg_or_tree).param_specs()
    leaves = jax.tree.leaves(tree, is_leaf=is_param_spec)
    total = 0
    for leaf in leaves:
        shape = leaf.shape if isinstance(leaf, ParamSpec) else np.shape(leaf)
        total += int(np.prod(shape)) if shape else 1
    return total


def stacked(spec: ParamSpec, n: int) -> ParamSpec:
    """Add a leading scan-over-layers ("stack") dimension."""
    return ParamSpec(
        shape=(n,) + spec.shape,
        logical=("stack",) + spec.logical,
        dtype=spec.dtype,
        init=spec.init,
        scale=spec.scale,
    )


def stack_tree(spec_tree: Any, n: int) -> Any:
    return jax.tree.map(lambda s: stacked(s, n), spec_tree, is_leaf=is_param_spec)
