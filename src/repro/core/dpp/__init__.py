from repro.core.dpp.master import (
    DPPMaster, SessionSpec, SessionState, Split, SplitFailure,
    FailureReport,
    REPORT_OK, REPORT_WORKER_LOST, REPORT_DATA_ERROR,
)
from repro.core.dpp.autoscale import (
    Decision, ElasticController, ElasticPolicy, Observation,
)
from repro.core.dpp.worker import DPPWorker, WorkerMetrics
from repro.core.dpp.client import DPPClient, SessionFailed
from repro.core.dpp.service import DPPService, DPPSession
from repro.core.dpp.prefetch import PrefetchMetrics, PrefetchPlanner
from repro.core.engine import (
    CompiledPlan, EngineStats, NumpyEngine, PallasEngine, TransformEngine,
    compile_pipeline, decode_plan, make_engine,
)
