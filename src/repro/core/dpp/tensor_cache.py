"""Preprocessed-tensor cache (§7.5: "we are also exploring ... caching
preprocessed tensors and balancing transformations between offline and
online ETL").

Keyed by (table, partition, split range, pipeline fingerprint) — exactly
the determinism boundary of a DPP split.  Because combo-window jobs share
the production-model baseline (§5.2), their feature projections and
transform DAGs overlap heavily; a warm cache converts repeated splits'
extract+transform cost into a memory copy.

``CacheStats`` quantifies the offline/online trade: bytes stored vs
CPU-seconds saved, the currency of the paper's power argument.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dpp.master import SessionSpec, Split
from repro.obs import counter, gauge


def pipeline_fingerprint(spec: SessionSpec) -> str:
    h = hashlib.sha1()
    h.update(repr(sorted(spec.feature_ids)).encode())
    for t in spec.transform_specs:
        h.update(repr((t.op, t.inputs, t.output, t.params)).encode())
    h.update(repr((spec.batch_size, spec.max_ids_per_feature)).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class CacheStats:
    hits: int = counter()
    misses: int = counter()
    bytes_stored: int = gauge()    # current occupancy: evictions shrink it
    cpu_s_saved: float = counter(0.0)
    evictions: int = counter()
    rejected: int = counter()      # inserts larger than the whole cache

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class TensorCache:
    """Bounded LRU of materialized split outputs (lists of tensor batches)."""

    def __init__(self, capacity_bytes: int = 256 * 1024 * 1024):
        self.capacity_bytes = capacity_bytes
        self._lock = threading.Lock()
        self._data: "OrderedDict[Tuple, Tuple[List[Dict[str, np.ndarray]], float]]" = OrderedDict()
        self.stats = CacheStats()

    @staticmethod
    def key(spec: SessionSpec, split: Split, generation: int = 0) -> Tuple:
        """A split's determinism boundary: (table, partition, row range,
        pipeline fingerprint) **plus the partition generation** — the
        warehouse bumps it on every ``rewrite_partition``, so rewritten
        bytes can never be served stale preprocessed tensors (the cached
        entries for the old generation simply age out of the LRU)."""
        return (spec.table, split.partition, generation,
                split.row_start, split.row_end, pipeline_fingerprint(spec))

    def get(self, key: Tuple) -> Optional[List[Dict[str, np.ndarray]]]:
        with self._lock:
            entry = self._data.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._data.move_to_end(key)
            self.stats.hits += 1
            self.stats.cpu_s_saved += entry[1]
            return entry[0]

    def put(self, key: Tuple, batches: List[Dict[str, np.ndarray]], cpu_s: float) -> None:
        """Insert a split's batches.  Idempotent on key: a split key fully
        determines its content (table, partition, row range, pipeline
        fingerprint), so concurrent workers racing on the same split may
        each call ``put`` — the first stored entry wins and later inserts
        only refresh its LRU recency instead of re-storing equal bytes."""
        nbytes = sum(sum(a.nbytes for a in b.values()) for b in batches)
        with self._lock:
            if nbytes > self.capacity_bytes:
                # an oversized insert would evict the entire cache and
                # still leave bytes_stored > capacity — refuse it instead
                self.stats.rejected += 1
                return
            if key in self._data:
                self._data.move_to_end(key)
                return
            while self.stats.bytes_stored + nbytes > self.capacity_bytes and self._data:
                _, (old, _) = self._data.popitem(last=False)
                self.stats.bytes_stored -= sum(
                    sum(a.nbytes for a in b.values()) for b in old
                )
                self.stats.evictions += 1
            self._data[key] = (batches, cpu_s)
            self.stats.bytes_stored += nbytes
